//! Per-time-point simulation streams derived from the event log
//! (DESIGN.md §Observability).
//!
//! A [`TimeSeriesRecorder`] is a cursor-bearing [`SimEvent`] consumer —
//! exactly-once delivery, like the campaign store's streaming CSV sink —
//! that turns the state-transition log into bounded per-point series:
//! queue depth, running jobs, dispatched-per-point, backfill starts vs
//! head-of-queue starts, per-type utilization, down-node count, and the
//! power draw/cap when an addon publishes them. The recorder is strictly
//! observation-only and gated by the [`crate::telemetry::Telemetry`]
//! handle: with it on or off, `jobs.csv`/`perf.csv` are byte-identical
//! (asserted in `rust/tests/observatory.rs`).
//!
//! Memory stays O(point budget) regardless of run length: whenever the
//! buffer reaches twice the budget it is compressed back to the budget
//! with largest-triangle-three-buckets (LTTB) downsampling — the
//! standard visual downsampler, which keeps the points spanning the
//! largest triangles with their neighbours and therefore preserves
//! spikes a stride-based decimator would erase. Selection is driven by
//! the queue-depth series (the headline dynamic); selected rows carry
//! all columns. Everything is a pure function of the event stream and
//! the sampled resource-manager state, so re-running the same
//! simulation reproduces `timeseries.csv` byte for byte.

use crate::resources::ResourceManager;
use crate::sim::SimEvent;
use crate::util::json::Json;
use crate::workload::JobId;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// File name of the per-run time-series artifact inside a run directory.
pub const TIMESERIES_FILE: &str = "timeseries.csv";

/// Default retained-point budget (the LTTB target size).
pub const DEFAULT_POINT_BUDGET: usize = 2000;

/// One retained time point of the derived streams.
#[derive(Debug, Clone, PartialEq)]
pub struct TsPoint {
    /// Simulation time of the closed point.
    pub t: u64,
    /// Queue length entering the point's dispatch cycle.
    pub queue: u32,
    /// Jobs running after the point's dispatch cycle.
    pub running: u32,
    /// Jobs dispatched at this point.
    pub started: u32,
    /// Starts whose job was at the head of the arrival order.
    pub head_starts: u32,
    /// Starts that jumped an earlier-arrived, still-queued job
    /// (backfill moves).
    pub backfill_starts: u32,
    /// Nodes down (failure windows / maintenance) at the point close.
    pub down_nodes: u32,
    /// Per-resource-type utilization in `[0, 1]`, in
    /// [`ResourceManager::resource_types`] order.
    pub util: Vec<f64>,
    /// System power draw in watts, when a power addon published it.
    pub power_w: Option<f64>,
    /// Active power cap in watts, when published.
    pub power_cap_w: Option<f64>,
}

/// Event-log consumer deriving bounded per-point time series (module
/// docs). Drive it with [`TimeSeriesRecorder::apply`] from its own log
/// cursor, call [`TimeSeriesRecorder::sample`] once after each advanced
/// step to capture resource-manager state, then
/// [`TimeSeriesRecorder::write`] the CSV and fold
/// [`TimeSeriesRecorder::summary`] into `telemetry.json`.
#[derive(Debug)]
pub struct TimeSeriesRecorder {
    types: Vec<String>,
    budget: usize,
    points: Vec<TsPoint>,
    /// Index of the first buffered point not yet filled by `sample`.
    unsampled: usize,
    // --- backfill classifier -------------------------------------------
    /// Monotone arrival counter; order of `Submitted` events.
    arrivals: u64,
    /// Still-queued jobs → their arrival sequence number.
    queued: BTreeMap<JobId, u64>,
    /// Starts classified since the last closed point.
    head_acc: u32,
    backfill_acc: u32,
    // --- whole-run aggregates (immune to compression) ------------------
    raw_points: u64,
    compressions: u64,
    head_total: u64,
    backfill_total: u64,
    queue_peak: u32,
    down_peak: u32,
    power_peak_w: Option<f64>,
}

impl TimeSeriesRecorder {
    /// A recorder for a system with the given resource types, using the
    /// default point budget.
    pub fn new(resource_types: &[String]) -> Self {
        Self::with_budget(resource_types, DEFAULT_POINT_BUDGET)
    }

    /// A recorder with an explicit retained-point budget (min 4: LTTB
    /// needs the two endpoints plus interior buckets).
    pub fn with_budget(resource_types: &[String], budget: usize) -> Self {
        TimeSeriesRecorder {
            types: resource_types.to_vec(),
            budget: budget.max(4),
            points: Vec::new(),
            unsampled: 0,
            arrivals: 0,
            queued: BTreeMap::new(),
            head_acc: 0,
            backfill_acc: 0,
            raw_points: 0,
            compressions: 0,
            head_total: 0,
            backfill_total: 0,
            queue_peak: 0,
            down_peak: 0,
            power_peak_w: None,
        }
    }

    /// Consume one log event. Queue transitions feed the backfill
    /// classifier; a closed point materializes a [`TsPoint`] whose
    /// sampled columns (utilization, down nodes, power) are filled by
    /// the next [`TimeSeriesRecorder::sample`] call.
    pub fn apply(&mut self, ev: &SimEvent) {
        match ev {
            SimEvent::Submitted { id, .. } => {
                self.arrivals += 1;
                self.queued.insert(*id, self.arrivals);
            }
            SimEvent::Started { id, .. } => {
                // A start is a *backfill* move when some earlier-arrived
                // job is still waiting; otherwise the head advanced.
                let seq = self.queued.remove(id).unwrap_or(0);
                if self.queued.values().any(|&s| s < seq) {
                    self.backfill_acc += 1;
                    self.backfill_total += 1;
                } else {
                    self.head_acc += 1;
                    self.head_total += 1;
                }
            }
            SimEvent::Rejected { id, .. } => {
                self.queued.remove(id);
            }
            SimEvent::Completed(_) => {}
            SimEvent::PointClosed(p) => {
                self.raw_points += 1;
                self.queue_peak = self.queue_peak.max(p.queue_len);
                self.points.push(TsPoint {
                    t: p.t,
                    queue: p.queue_len,
                    running: p.running,
                    started: p.started,
                    head_starts: self.head_acc,
                    backfill_starts: self.backfill_acc,
                    down_nodes: 0,
                    util: Vec::new(),
                    power_w: None,
                    power_cap_w: None,
                });
                self.head_acc = 0;
                self.backfill_acc = 0;
            }
        }
    }

    /// Fill the sampled columns (per-type utilization, down-node count,
    /// published power values) of every point closed since the last
    /// call, then enforce the memory bound. Call once per advanced step,
    /// after draining the recorder's cursor — a checkpoint restore
    /// replays its whole event-log prefix into the first drain, so those
    /// points all receive the restore-time sample (the one resume
    /// caveat; event-derived columns replay exactly).
    pub fn sample(&mut self, rm: &ResourceManager, extra: &BTreeMap<String, f64>) {
        if self.unsampled < self.points.len() {
            let util: Vec<f64> = (0..self.types.len()).map(|i| rm.utilization(i)).collect();
            let down = (0..rm.num_nodes()).filter(|&n| rm.is_node_down(n)).count() as u32;
            let power = extra.get("power.system_w").copied();
            let cap = extra.get("power.cap_w").copied();
            self.down_peak = self.down_peak.max(down);
            if let Some(w) = power {
                self.power_peak_w =
                    Some(self.power_peak_w.map_or(w, |p: f64| p.max(w)));
            }
            for p in &mut self.points[self.unsampled..] {
                p.util.clone_from(&util);
                p.down_nodes = down;
                p.power_w = power;
                p.power_cap_w = cap;
            }
            self.unsampled = self.points.len();
        }
        self.maybe_compress();
    }

    /// Compress the buffer back to the budget once it doubles it. Only
    /// fully sampled prefixes are compressed, so `sample` never loses
    /// track of pending rows.
    fn maybe_compress(&mut self) {
        if self.points.len() < self.budget * 2 || self.unsampled < self.points.len() {
            return;
        }
        let xs: Vec<f64> = self.points.iter().map(|p| p.t as f64).collect();
        let ys: Vec<f64> = self.points.iter().map(|p| p.queue as f64).collect();
        let keep = lttb_indices(&xs, &ys, self.budget);
        let mut kept = Vec::with_capacity(keep.len());
        for i in keep {
            kept.push(self.points[i].clone());
        }
        self.points = kept;
        self.unsampled = self.points.len();
        self.compressions += 1;
    }

    /// Retained points (≤ 2× budget mid-run, ≤ budget after
    /// [`TimeSeriesRecorder::write`]).
    pub fn points(&self) -> &[TsPoint] {
        &self.points
    }

    /// Raw time points observed before downsampling.
    pub fn raw_points(&self) -> u64 {
        self.raw_points
    }

    /// The CSV header for this recorder's column set.
    pub fn csv_header(&self) -> String {
        let mut h =
            String::from("t,queue,running,started,head_starts,backfill_starts,down_nodes");
        for ty in &self.types {
            h.push_str(",util_");
            h.push_str(ty);
        }
        h.push_str(",power_w,power_cap_w");
        h
    }

    /// Final LTTB pass down to the budget, then write
    /// `<dir>/timeseries.csv` and return its path. Power columns are
    /// empty when no addon ever published them.
    pub fn write(&mut self, dir: &Path) -> anyhow::Result<PathBuf> {
        if self.points.len() > self.budget {
            let xs: Vec<f64> = self.points.iter().map(|p| p.t as f64).collect();
            let ys: Vec<f64> = self.points.iter().map(|p| p.queue as f64).collect();
            let keep = lttb_indices(&xs, &ys, self.budget);
            self.points = keep.into_iter().map(|i| self.points[i].clone()).collect();
            self.unsampled = self.points.len();
            self.compressions += 1;
        }
        let mut csv = self.csv_header();
        csv.push('\n');
        let fmt_opt = |v: Option<f64>| v.map(|w| format!("{w:.3}")).unwrap_or_default();
        for p in &self.points {
            csv.push_str(&format!(
                "{},{},{},{},{},{},{}",
                p.t, p.queue, p.running, p.started, p.head_starts, p.backfill_starts,
                p.down_nodes
            ));
            for u in &p.util {
                csv.push_str(&format!(",{u:.6}"));
            }
            // short rows can only come from an unsampled tail (no
            // `sample` call after the final drain); pad the columns
            for _ in p.util.len()..self.types.len() {
                csv.push_str(",0.000000");
            }
            csv.push_str(&format!(",{},{}\n", fmt_opt(p.power_w), fmt_opt(p.power_cap_w)));
        }
        let path = dir.join(TIMESERIES_FILE);
        std::fs::write(&path, csv)?;
        Ok(path)
    }

    /// The summary block folded into `telemetry.json` under
    /// `"timeseries"`: whole-run aggregates that survive downsampling.
    pub fn summary(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("points_raw".to_string(), Json::Num(self.raw_points as f64));
        m.insert("points_kept".to_string(), Json::Num(self.points.len() as f64));
        m.insert("budget".to_string(), Json::Num(self.budget as f64));
        m.insert("compressions".to_string(), Json::Num(self.compressions as f64));
        m.insert("head_starts".to_string(), Json::Num(self.head_total as f64));
        m.insert("backfill_starts".to_string(), Json::Num(self.backfill_total as f64));
        m.insert("queue_peak".to_string(), Json::Num(self.queue_peak as f64));
        m.insert("down_nodes_peak".to_string(), Json::Num(self.down_peak as f64));
        if let Some(w) = self.power_peak_w {
            m.insert("power_peak_w".to_string(), Json::Num(w));
        }
        Json::Obj(m)
    }
}

/// Largest-triangle-three-buckets downsampling: return the (sorted,
/// deduplicated) indices of the `budget` points to keep from the series
/// `(xs, ys)`. The first and last points are always kept; every interior
/// bucket contributes the point forming the largest triangle with the
/// previously selected point and the next bucket's centroid. Pure and
/// deterministic — equal inputs select equal indices.
pub fn lttb_indices(xs: &[f64], ys: &[f64], budget: usize) -> Vec<usize> {
    let n = xs.len();
    debug_assert_eq!(n, ys.len());
    if n <= budget || budget < 3 {
        return (0..n).collect();
    }
    let mut keep = Vec::with_capacity(budget);
    keep.push(0);
    let buckets = budget - 2;
    // interior points [1, n-1) split into `buckets` equal ranges
    let span = (n - 2) as f64 / buckets as f64;
    let mut prev = 0usize;
    for b in 0..buckets {
        let lo = 1 + (b as f64 * span) as usize;
        let hi = (1 + ((b + 1) as f64 * span) as usize).min(n - 1);
        // centroid of the *next* bucket (the last one averages the end)
        let (nlo, nhi) = if b + 1 < buckets {
            (1 + ((b + 1) as f64 * span) as usize, (1 + ((b + 2) as f64 * span) as usize).min(n - 1))
        } else {
            (n - 1, n)
        };
        let m = (nhi - nlo).max(1) as f64;
        let cx = xs[nlo..nhi].iter().sum::<f64>() / m;
        let cy = ys[nlo..nhi].iter().sum::<f64>() / m;
        let (px, py) = (xs[prev], ys[prev]);
        let mut best = lo;
        let mut best_area = -1.0f64;
        for i in lo..hi.max(lo + 1) {
            // twice the triangle area; ties keep the earliest index
            let area = ((px - cx) * (ys[i] - py) - (px - xs[i]) * (cy - py)).abs();
            if area > best_area {
                best_area = area;
                best = i;
            }
        }
        keep.push(best);
        prev = best;
    }
    keep.push(n - 1);
    keep.dedup();
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::output::PerfRecord;

    fn point(t: u64, queue: u32, started: u32) -> SimEvent {
        SimEvent::PointClosed(PerfRecord {
            t,
            dispatch_ns: 0,
            other_ns: 0,
            queue_len: queue,
            running: 0,
            started,
            rss_kb: 0,
        })
    }

    #[test]
    fn lttb_keeps_endpoints_and_spikes() {
        let n = 1000;
        let xs: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut ys = vec![1.0f64; n];
        ys[500] = 100.0; // the spike a decimator would drop
        let keep = lttb_indices(&xs, &ys, 50);
        assert!(keep.len() <= 50);
        assert_eq!(keep[0], 0);
        assert_eq!(*keep.last().unwrap(), n - 1);
        assert!(keep.contains(&500), "LTTB must retain the spike: {keep:?}");
        assert!(keep.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
        // determinism
        assert_eq!(keep, lttb_indices(&xs, &ys, 50));
        // short series pass through untouched
        assert_eq!(lttb_indices(&xs[..10], &ys[..10], 50), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn backfill_classifier_counts_jumps() {
        let mut rec = TimeSeriesRecorder::new(&["core".to_string()]);
        rec.apply(&SimEvent::Submitted { t: 0, id: 1 });
        rec.apply(&SimEvent::Submitted { t: 0, id: 2 });
        rec.apply(&SimEvent::Submitted { t: 0, id: 3 });
        // job 2 starts while job 1 still queues: a backfill move
        rec.apply(&SimEvent::Started { t: 1, id: 2 });
        // then the head advances
        rec.apply(&SimEvent::Started { t: 1, id: 1 });
        rec.apply(&SimEvent::Started { t: 1, id: 3 });
        rec.apply(&point(1, 0, 3));
        assert_eq!(rec.points()[0].backfill_starts, 1);
        assert_eq!(rec.points()[0].head_starts, 2);
        assert_eq!((rec.backfill_total, rec.head_total), (1, 2));
    }

    #[test]
    fn buffer_stays_within_twice_the_budget() {
        let types = vec!["core".to_string()];
        let mut rec = TimeSeriesRecorder::with_budget(&types, 16);
        let rm = ResourceManager::from_config(&crate::config::SysConfig::homogeneous(
            "ts",
            2,
            &[("core", 4)],
            0,
        ));
        let extra = BTreeMap::new();
        for t in 0..500u64 {
            rec.apply(&point(t, (t % 7) as u32, 0));
            rec.sample(&rm, &extra);
        }
        assert!(rec.points().len() < 32, "buffer {} breached 2x budget", rec.points().len());
        assert_eq!(rec.raw_points(), 500);
        let s = rec.summary();
        assert_eq!(s.get("points_raw").unwrap().as_u64(), Some(500));
        assert!(s.get("compressions").unwrap().as_u64().unwrap() > 0);
        assert_eq!(s.get("queue_peak").unwrap().as_u64(), Some(6));
        assert!(s.get("power_peak_w").is_none(), "no power addon, no power key");
    }

    #[test]
    fn write_is_deterministic_and_budget_bounded() {
        let tmp = crate::testutil::tempdir().unwrap();
        let types = vec!["core".to_string(), "mem".to_string()];
        let sys =
            crate::config::SysConfig::homogeneous("ts", 2, &[("core", 4), ("mem", 16)], 0);
        let rm = ResourceManager::from_config(&sys);
        let run = |dir: &Path| {
            let mut rec = TimeSeriesRecorder::with_budget(&types, 32);
            let extra: BTreeMap<String, f64> =
                [("power.system_w".to_string(), 123.456)].into_iter().collect();
            for t in 0..300u64 {
                rec.apply(&SimEvent::Submitted { t, id: t + 1 });
                rec.apply(&SimEvent::Started { t, id: t + 1 });
                rec.apply(&point(t, (t % 11) as u32, 1));
                rec.sample(&rm, &extra);
            }
            rec.write(dir).unwrap()
        };
        let a = run(tmp.path());
        let text = std::fs::read_to_string(&a).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].starts_with("t,queue,running,started,head_starts,backfill_starts"));
        assert!(lines[0].contains("util_core") && lines[0].contains("util_mem"));
        assert!(lines.len() - 1 <= 32, "{} rows exceed the budget", lines.len() - 1);
        assert!(lines[1].ends_with(",123.456,"), "power column present, cap empty: {}", lines[1]);
        let dir2 = tmp.path().join("again");
        std::fs::create_dir_all(&dir2).unwrap();
        let b = run(&dir2);
        assert_eq!(text, std::fs::read_to_string(&b).unwrap(), "re-run must be byte-identical");
    }
}
