//! Span tracing with Chrome trace-event JSON export.
//!
//! The tracer buffers completed spans (`ph: "X"` events) and serializes
//! them as the Chrome trace-event format, loadable in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`. Spans come from
//! synchronous call stacks — an `allocator_place` span always lies
//! inside its `dispatch_cycle` span — so the single-thread `pid/tid`
//! timeline nests correctly. Events are recorded at span *completion*,
//! which means children appear before their parent in the buffer; the
//! format is order-insensitive, and viewers sort by timestamp.

use super::metrics::SpanKind;
use std::fmt::Write as _;

/// Default cap on buffered trace events (~4M ≈ a few hundred MB of
/// JSON); past it new events are dropped and counted, never reallocated
/// into oblivion mid-run.
pub const DEFAULT_TRACE_CAP: usize = 4_000_000;

/// One completed span.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// What was timed.
    pub kind: SpanKind,
    /// Start offset from the telemetry epoch, nanoseconds.
    pub ts_ns: u64,
    /// Duration, nanoseconds.
    pub dur_ns: u64,
    /// The span's numeric argument (see [`SpanKind::arg_name`]).
    pub arg: u64,
}

/// A bounded buffer of completed spans.
#[derive(Debug)]
pub struct Tracer {
    events: Vec<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::with_capacity(DEFAULT_TRACE_CAP)
    }
}

impl Tracer {
    /// A tracer that keeps at most `cap` events.
    pub fn with_capacity(cap: usize) -> Self {
        Tracer { events: Vec::new(), cap: cap.max(1), dropped: 0 }
    }

    /// Record one completed span. Returns `false` when the event was
    /// dropped because the buffer is at capacity.
    pub fn record(&mut self, ev: TraceEvent) -> bool {
        if self.events.len() >= self.cap {
            self.dropped += 1;
            false
        } else {
            self.events.push(ev);
            true
        }
    }

    /// Buffered events, in completion order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Events dropped at capacity.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Serialize the buffer as Chrome trace-event JSON (the
    /// `traceEvents` object form). Timestamps/durations are written in
    /// microseconds with nanosecond precision (3 decimals), on one
    /// `pid: 1` / `tid: 1` timeline.
    pub fn to_chrome_json(&self) -> String {
        // ~120 bytes per serialized event
        let mut out = String::with_capacity(64 + self.events.len() * 120);
        out.push_str("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[");
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n{{\"name\":\"{}\",\"cat\":\"sim\",\"ph\":\"X\",\"ts\":{}.{:03},\
                 \"dur\":{}.{:03},\"pid\":1,\"tid\":1,\"args\":{{\"{}\":{}}}}}",
                ev.kind.name(),
                ev.ts_ns / 1_000,
                ev.ts_ns % 1_000,
                ev.dur_ns / 1_000,
                ev.dur_ns % 1_000,
                ev.kind.arg_name(),
                ev.arg
            );
        }
        out.push_str("\n]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn ev(kind: SpanKind, ts_ns: u64, dur_ns: u64, arg: u64) -> TraceEvent {
        TraceEvent { kind, ts_ns, dur_ns, arg }
    }

    #[test]
    fn chrome_json_parses_and_round_trips_fields() {
        let mut t = Tracer::default();
        t.record(ev(SpanKind::Place, 1_500, 500, 4));
        t.record(ev(SpanKind::DispatchCycle, 1_000, 2_000, 7));
        let text = t.to_chrome_json();
        let v = Json::parse(&text).expect("valid JSON");
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        let first = &evs[0];
        assert_eq!(first.get("name").unwrap().as_str(), Some("allocator_place"));
        assert_eq!(first.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(first.get("ts").unwrap().as_f64(), Some(1.5)); // µs
        assert_eq!(first.get("dur").unwrap().as_f64(), Some(0.5));
        assert_eq!(first.get("args").unwrap().get("slots").unwrap().as_u64(), Some(4));
        let second = &evs[1];
        assert_eq!(second.get("name").unwrap().as_str(), Some("dispatch_cycle"));
        assert_eq!(second.get("args").unwrap().get("queue_len").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn empty_tracer_is_valid_json() {
        let t = Tracer::default();
        let v = Json::parse(&t.to_chrome_json()).unwrap();
        assert_eq!(v.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn capacity_cap_drops_and_counts() {
        let mut t = Tracer::with_capacity(2);
        assert!(t.record(ev(SpanKind::Place, 0, 1, 0)));
        assert!(t.record(ev(SpanKind::Place, 1, 1, 0)));
        assert!(!t.record(ev(SpanKind::Place, 2, 1, 0)));
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.dropped(), 1);
        // the serialized buffer still parses
        assert!(Json::parse(&t.to_chrome_json()).is_ok());
    }
}
