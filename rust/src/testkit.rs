//! A miniature property-based testing kit (offline substitute for
//! `proptest`): seeded random case generation with failing-seed reporting.
//! Coordinator invariants in `rust/tests/prop_invariants.rs` are built on
//! this.

#![doc(hidden)]

use crate::rng::Pcg64;

/// Run `cases` random property checks. Each case gets an independent,
/// deterministic RNG derived from `base_seed`; on panic the failing case
/// seed is printed so the case can be replayed exactly.
pub fn check<F: Fn(&mut Pcg64) + std::panic::RefUnwindSafe>(
    name: &str,
    base_seed: u64,
    cases: u32,
    prop: F,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg64::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property {name:?} failed on case {case} (replay seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random job generator used by coordinator property tests.
pub fn arb_job(rng: &mut Pcg64, id: u64, max_slots: u32, types: usize) -> crate::workload::Job {
    let slots = rng.range_u64(1, max_slots as u64) as u32;
    let per_slot = (0..types)
        .map(|r| if r == 0 { 1 } else { rng.range_u64(0, 8) })
        .collect();
    let duration = rng.range_u64(0, 5_000);
    crate::workload::Job {
        id,
        submit: rng.range_u64(0, 50_000),
        duration,
        // estimates are wrong on purpose: dispatchers must tolerate it
        req_time: (duration as f64 * rng.range_f64(0.5, 4.0)) as u64 + 1,
        slots,
        per_slot,
        user: rng.next_u32() % 16,
        app: rng.next_u32() % 8,
        status: 1,
        shape: crate::resources::ShapeId::UNSET,
    }
}

/// Random batch of jobs with distinct ids.
pub fn arb_jobs(
    rng: &mut Pcg64,
    n: usize,
    max_slots: u32,
    types: usize,
) -> Vec<crate::workload::Job> {
    (0..n).map(|i| arb_job(rng, i as u64 + 1, max_slots, types)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let counter = std::sync::atomic::AtomicU32::new(0);
        check("count", 1, 25, |_rng| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        check("fail", 2, 10, |rng| {
            assert!(rng.f64() < 0.5, "eventually fails");
        });
    }

    #[test]
    fn arb_jobs_well_formed() {
        let mut rng = Pcg64::new(3);
        for j in arb_jobs(&mut rng, 100, 8, 3) {
            assert!(j.slots >= 1 && j.slots <= 8);
            assert_eq!(j.per_slot.len(), 3);
            assert_eq!(j.per_slot[0], 1);
            assert!(j.req_time >= 1);
        }
    }
}
