//! Test utilities (offline substitute for the `tempfile` crate).
//!
//! Test modules import this as `use crate::testutil as tempfile;` so the
//! familiar `tempfile::tempdir()` idiom keeps working. Integration tests use
//! `use accasim::testutil as tempfile;`.

#![doc(hidden)]

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A uniquely named temporary directory removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Create a fresh temporary directory under the system temp dir.
pub fn tempdir() -> std::io::Result<TempDir> {
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir().join(format!(
        "accasim-test-{}-{}-{}",
        std::process::id(),
        n,
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0)
    ));
    std::fs::create_dir_all(&path)?;
    Ok(TempDir { path })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_exists_and_cleans_up() {
        let p;
        {
            let d = tempdir().unwrap();
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("x"), "y").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn tempdirs_unique() {
        let a = tempdir().unwrap();
        let b = tempdir().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
