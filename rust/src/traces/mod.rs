//! Deterministic trace synthesizers for the paper's three evaluation
//! datasets (§6.2): Seth (HPC2N), RICC and MetaCentrum.
//!
//! The original SWF archives are online downloads we cannot fetch here, so
//! each synthesizer reproduces the *documented statistics* of its dataset —
//! job count, time span, system size, office-hours arrival cycle, job-size
//! mix and heavy-tailed durations — and emits a real SWF file plus the
//! matching system configuration (see DESIGN.md §Substitutions). Scaled-
//! down variants (`scale < 1`) keep the arrival *rate* (span shrinks with
//! the job count) so queueing behaviour is preserved.

use crate::config::SysConfig;
use crate::rng::Pcg64;
use crate::workload::{SwfFields, SwfWriter, WorkloadWriter};
use std::path::Path;

/// Statistical description of a synthesized trace.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub name: &'static str,
    /// Paper-reported job count (full size).
    pub jobs: u64,
    /// Paper-reported time span in seconds (full size).
    pub span_seconds: u64,
    /// Node count and per-node shape.
    pub nodes: u64,
    pub cores_per_node: u64,
    pub mem_per_node_mb: u64,
    /// Fraction of serial (1-proc) jobs.
    pub serial_frac: f64,
    /// Max processors a job may request.
    pub max_procs: u64,
    /// Log-normal duration parameters (seconds).
    pub dur_mu: f64,
    pub dur_sigma: f64,
    /// System start epoch (so dates fall in a realistic range).
    pub epoch: u64,
}

/// Seth (HPC2N): 202,871 jobs over ~3.5 years; 120 nodes / 480 cores /
/// 120 GB RAM.
pub const SETH: TraceSpec = TraceSpec {
    name: "seth",
    jobs: 202_871,
    span_seconds: 110_000_000,
    nodes: 120,
    cores_per_node: 4,
    mem_per_node_mb: 1024,
    serial_frac: 0.35,
    max_procs: 128,
    dur_mu: 7.3,    // median ≈ 25 min; tuned for ~0.85 steady utilization
    dur_sigma: 2.0, // heavy tail up to days
    epoch: 1_025_827_200, // 2002-07-05
};

/// RICC: 447,794 jobs over 5 months; 1,024 nodes / 8,192 cores / 12 TB RAM.
pub const RICC: TraceSpec = TraceSpec {
    name: "ricc",
    jobs: 447_794,
    span_seconds: 13_100_000,
    nodes: 1_024,
    cores_per_node: 8,
    mem_per_node_mb: 12_288,
    serial_frac: 0.55,
    max_procs: 1024,
    dur_mu: 5.45,   // tuned for ~0.8 steady utilization
    dur_sigma: 2.2,
    epoch: 1_272_672_000, // 2010-05-01
};

/// MetaCentrum: 5,731,100 jobs over ~2.25 years; 495 nodes / 8,412 cores /
/// 10 TB RAM (19 heterogeneous clusters; we model 3 node groups).
pub const METACENTRUM: TraceSpec = TraceSpec {
    name: "mc",
    jobs: 5_731_100,
    span_seconds: 71_000_000,
    nodes: 495,
    cores_per_node: 17,
    mem_per_node_mb: 20_480,
    serial_frac: 0.70,
    max_procs: 512,
    dur_mu: 5.05,   // tuned for ~0.75 steady utilization
    dur_sigma: 2.4,
    epoch: 1_357_027_200, // 2013-01-01
};

/// All three paper datasets.
pub const ALL: &[&TraceSpec] = &[&SETH, &RICC, &METACENTRUM];

/// Look a spec up by name.
pub fn spec_by_name(name: &str) -> Option<&'static TraceSpec> {
    ALL.iter().copied().find(|s| s.name.eq_ignore_ascii_case(name))
}

/// Office-hours modulation of arrivals: weekday working hours are ~6× more
/// likely than nights/weekends (the shape seen in Figs 14–15).
fn arrival_weight(t: u64) -> f64 {
    let hour = (t % 86_400) / 3_600;
    let dow = ((t / 86_400) + 3) % 7;
    let day_w = if dow >= 5 { 0.35 } else { 1.0 };
    let hour_w = match hour {
        8..=17 => 1.0,
        18..=22 => 0.5,
        _ => 0.15,
    };
    day_w * hour_w
}

impl TraceSpec {
    /// The matching system configuration.
    pub fn sys_config(&self) -> SysConfig {
        if self.name == "mc" {
            // heterogeneous: three groups approximating the grid mix
            SysConfig::from_json(&format!(
                r#"{{
                    "system_name": "MetaCentrum",
                    "start_time": {epoch},
                    "groups": {{
                        "small":  {{ "core": 8,  "mem": 16384 }},
                        "medium": {{ "core": 16, "mem": 20480 }},
                        "large":  {{ "core": 32, "mem": 65536 }}
                    }},
                    "resources": {{ "small": 150, "medium": 250, "large": 95 }}
                }}"#,
                epoch = self.epoch
            ))
            .expect("static MC config is valid")
        } else {
            SysConfig::homogeneous(
                self.name,
                self.nodes,
                &[("core", self.cores_per_node), ("mem", self.mem_per_node_mb)],
                self.epoch,
            )
        }
    }

    /// Number of jobs at a given scale.
    pub fn scaled_jobs(&self, scale: f64) -> u64 {
        ((self.jobs as f64 * scale).round() as u64).max(1)
    }

    /// Synthesize the trace into an SWF file. `scale ∈ (0, 1]` shrinks the
    /// job count (and span proportionally). Returns the job count written.
    pub fn synthesize<P: AsRef<Path>>(&self, path: P, scale: f64, seed: u64) -> anyhow::Result<u64> {
        let n = self.scaled_jobs(scale);
        let span = ((self.span_seconds as f64 * scale).round() as u64).max(n);
        let mean_gap = (span as f64 / n as f64).max(0.01);
        let mut rng = Pcg64::new(seed ^ 0xACCA_51B5);
        let header = vec![
            format!("Synthetic {} trace (accasim-rs); {} jobs", self.name, n),
            format!("MaxNodes: {}", self.nodes),
            format!("MaxProcs: {}", self.nodes * self.cores_per_node),
            "UnitTime: seconds".to_string(),
        ];
        let mut w = SwfWriter::create(path, &header)?;
        let mut t = self.epoch as f64;
        let total_cores = (self.nodes * self.cores_per_node) as f64;
        for i in 0..n {
            // thinned Poisson arrivals modulated by the office-hours cycle
            loop {
                t += rng.exponential(1.0 / mean_gap) / 0.6;
                if rng.f64() < arrival_weight(t as u64) {
                    break;
                }
            }
            let procs = if rng.f64() < self.serial_frac {
                1
            } else {
                // log2-uniform parallel sizes, biased to powers of two
                let max_log = (self.max_procs.min(total_cores as u64) as f64).log2();
                let bits = rng.range_f64(1.0, max_log);
                let p = (2f64.powf(bits)).round() as u64;
                if rng.f64() < 0.75 {
                    p.next_power_of_two().min(self.max_procs)
                } else {
                    p.max(2)
                }
            };
            let duration = rng.lognormal(self.dur_mu, self.dur_sigma).clamp(1.0, 5.0 * 86_400.0)
                as i64;
            // users overestimate: 1–8× the duration, occasionally maxed out
            let req_time = if rng.f64() < 0.1 {
                5 * 86_400
            } else {
                (duration as f64 * rng.range_f64(1.0, 8.0)) as i64
            };
            let mem_per_proc_kb =
                rng.range_u64(64, (self.mem_per_node_mb / self.cores_per_node).max(65)) * 1024;
            let fields = SwfFields {
                job_number: (i + 1) as i64,
                submit_time: t as i64,
                wait_time: -1,
                run_time: duration,
                allocated_procs: procs as i64,
                avg_cpu_time: -1,
                used_memory: -1,
                requested_procs: procs as i64,
                requested_time: req_time.max(1),
                requested_memory: mem_per_proc_kb as i64,
                status: 1,
                user_id: 1 + (rng.next_u32() % 211) as i64,
                group_id: 1 + (rng.next_u32() % 13) as i64,
                app_id: 1 + (rng.next_u32() % 101) as i64,
                queue_id: 1,
                partition_id: 1,
                preceding_job: -1,
                think_time: -1,
            };
            w.write_job(&fields)?;
        }
        w.finish()?;
        Ok(n)
    }
}

impl TraceSpec {
    /// Synthesize (idempotently) the seed-tagged *realization*
    /// `<name>-x<scale bits>-seed<seed>.swf` of this trace into `dir` and
    /// return its path. Distinct seeds yield distinct realizations of the
    /// same statistical workload — the campaign engine keys realizations on
    /// the repetition seed so repetitions actually vary while every
    /// dispatcher within a repetition sees identical input. The scale is
    /// encoded as its exact f64 bit pattern: two scales that merely *round*
    /// to the same value must never share a cached realization file.
    pub fn realization<P: AsRef<Path>>(
        &self,
        dir: P,
        scale: f64,
        seed: u64,
    ) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir.as_ref())?;
        let file = format!("{}-x{:016x}-seed{}.swf", self.name, scale.to_bits(), seed);
        let path = dir.as_ref().join(file);
        if !path.exists() {
            self.synthesize(&path, scale, seed)?;
        }
        Ok(path)
    }
}

/// Synthesize a trace and its config into a directory (idempotent: skips
/// files that already exist). Returns `(swf path, config path)`.
pub fn materialize<P: AsRef<Path>>(
    spec: &TraceSpec,
    dir: P,
    scale: f64,
    seed: u64,
) -> anyhow::Result<(std::path::PathBuf, std::path::PathBuf)> {
    std::fs::create_dir_all(dir.as_ref())?;
    let tag = if (scale - 1.0).abs() < 1e-9 {
        spec.name.to_string()
    } else {
        format!("{}_s{}", spec.name, (scale * 1000.0).round() as u64)
    };
    let swf = dir.as_ref().join(format!("{tag}.swf"));
    let cfg = dir.as_ref().join(format!("{}.json", spec.name));
    if !swf.exists() {
        spec.synthesize(&swf, scale, seed)?;
    }
    if !cfg.exists() {
        spec.sys_config().write_json_file(&cfg)?;
    }
    Ok((swf, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    #[allow(unused_imports)]
    use crate::testutil as tempfile;
    use crate::workload::SwfReader;

    #[test]
    fn specs_match_paper_numbers() {
        assert_eq!(SETH.jobs, 202_871);
        assert_eq!(RICC.jobs, 447_794);
        assert_eq!(METACENTRUM.jobs, 5_731_100);
        assert_eq!(SETH.nodes * SETH.cores_per_node, 480);
        assert_eq!(RICC.nodes * RICC.cores_per_node, 8192);
    }

    #[test]
    fn sys_configs_valid() {
        for spec in ALL {
            let cfg = spec.sys_config();
            cfg.validate().unwrap();
            assert_eq!(cfg.total_nodes(), spec.nodes, "{}", spec.name);
        }
        // MC heterogeneity: 3 groups, ~8412 cores
        let mc = METACENTRUM.sys_config();
        assert_eq!(mc.groups.len(), 3);
        let cores = mc.total_of("core");
        assert!((8000..9000).contains(&cores), "mc cores = {cores}");
    }

    #[test]
    fn synthesize_small_trace() {
        let dir = tempfile::tempdir().unwrap();
        let p = dir.path().join("seth.swf");
        let n = SETH.synthesize(&p, 0.001, 1).unwrap();
        assert_eq!(n, 203);
        let r = SwfReader::open(&p).unwrap();
        let jobs: Vec<_> = r.map(|x| x.unwrap()).collect();
        assert_eq!(jobs.len(), 203);
        // submissions increasing
        assert!(jobs.windows(2).all(|w| w[0].submit_time <= w[1].submit_time));
        // fields sane
        for j in &jobs {
            assert!(j.run_time >= 1);
            assert!(j.requested_procs >= 1);
            assert!(j.requested_procs <= 480);
            assert!(j.requested_time >= j.run_time.min(5 * 86_400));
        }
    }

    #[test]
    fn serial_fraction_approximated() {
        let dir = tempfile::tempdir().unwrap();
        let p = dir.path().join("ricc.swf");
        RICC.synthesize(&p, 0.005, 2).unwrap();
        let r = SwfReader::open(&p).unwrap();
        let jobs: Vec<_> = r.map(|x| x.unwrap()).collect();
        let serial = jobs.iter().filter(|j| j.requested_procs == 1).count() as f64
            / jobs.len() as f64;
        assert!((serial - RICC.serial_frac).abs() < 0.07, "serial={serial}");
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let dir = tempfile::tempdir().unwrap();
        let (a, b, c) = (
            dir.path().join("a.swf"),
            dir.path().join("b.swf"),
            dir.path().join("c.swf"),
        );
        SETH.synthesize(&a, 0.0005, 7).unwrap();
        SETH.synthesize(&b, 0.0005, 7).unwrap();
        SETH.synthesize(&c, 0.0005, 8).unwrap();
        let read = |p| std::fs::read_to_string(p).unwrap();
        assert_eq!(read(&a), read(&b));
        assert_ne!(read(&a), read(&c));
    }

    #[test]
    fn materialize_idempotent() {
        let dir = tempfile::tempdir().unwrap();
        let (swf1, cfg1) = materialize(&SETH, dir.path(), 0.0005, 1).unwrap();
        let mtime = std::fs::metadata(&swf1).unwrap().modified().unwrap();
        let (swf2, _cfg2) = materialize(&SETH, dir.path(), 0.0005, 1).unwrap();
        assert_eq!(swf1, swf2);
        assert_eq!(std::fs::metadata(&swf2).unwrap().modified().unwrap(), mtime);
        assert!(cfg1.exists());
    }

    #[test]
    fn realizations_keyed_by_seed() {
        let dir = tempfile::tempdir().unwrap();
        let a = SETH.realization(dir.path(), 0.0005, 1).unwrap();
        let b = SETH.realization(dir.path(), 0.0005, 2).unwrap();
        let a2 = SETH.realization(dir.path(), 0.0005, 1).unwrap();
        assert_eq!(a, a2, "same seed resolves to the same file");
        assert_ne!(a, b);
        let read = |p: &std::path::PathBuf| std::fs::read_to_string(p).unwrap();
        assert_ne!(read(&a), read(&b), "different seeds differ");
        // idempotent: the second call must not rewrite
        let mtime = std::fs::metadata(&a).unwrap().modified().unwrap();
        SETH.realization(dir.path(), 0.0005, 1).unwrap();
        assert_eq!(std::fs::metadata(&a).unwrap().modified().unwrap(), mtime);
    }

    #[test]
    fn office_hours_shape() {
        let dir = tempfile::tempdir().unwrap();
        let p = dir.path().join("s.swf");
        SETH.synthesize(&p, 0.002, 3).unwrap();
        let r = SwfReader::open(&p).unwrap();
        let times: Vec<u64> = r.map(|x| x.unwrap().submit_time as u64).collect();
        let (hourly, daily, _) = crate::plotdata::submission_distributions(&times);
        let work: f64 = hourly[8..18].iter().sum();
        assert!(work > 0.55, "working-hours share {work}");
        let weekend = daily[5] + daily[6];
        assert!(weekend < 0.2, "weekend share {weekend}");
    }
}
