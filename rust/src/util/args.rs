//! A tiny CLI argument parser (offline substitute for `clap`): positionals,
//! `--key value`, `--key=value` and boolean `--flag`s, with typed accessors
//! and unknown-option detection.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.options.get(key).cloned()
    }

    /// Typed option with default; errors on unparsable values.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// Boolean flag (also accepts `--key true/false`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
            || self.options.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// Error on options/flags never consumed by the command (typo guard).
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        anyhow::ensure!(unknown.is_empty(), "unknown option(s): {unknown:?}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("simulate w.swf --sys cfg.json --reps 3 --verbose");
        assert_eq!(a.positionals, vec!["simulate", "w.swf"]);
        assert_eq!(a.get("sys", ""), "cfg.json");
        assert_eq!(a.get_parse::<u32>("reps", 1).unwrap(), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--scale=0.5 --name=x");
        assert_eq!(a.get_parse::<f64>("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get("name", ""), "x");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("cmd");
        assert_eq!(a.get("missing", "dflt"), "dflt");
        assert_eq!(a.get_parse::<u64>("jobs", 50_000).unwrap(), 50_000);
        assert!(a.get_opt("missing").is_none());
    }

    #[test]
    fn bad_parse_errors() {
        let a = parse("--reps abc");
        assert!(a.get_parse::<u32>("reps", 1).is_err());
    }

    #[test]
    fn unknown_detection() {
        let a = parse("--known 1 --typo 2");
        let _ = a.get_parse::<u32>("known", 0).unwrap();
        let err = a.reject_unknown().unwrap_err();
        assert!(err.to_string().contains("typo"));
        let _ = a.get("typo", "");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b val");
        assert!(a.flag("a"));
        assert_eq!(a.get("b", ""), "val");
    }
}
