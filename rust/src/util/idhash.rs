//! A fast integer-key hasher for job-id maps. The default SipHash showed up
//! at ~4% of a Table-1 run (EXPERIMENTS.md §Perf); job ids need no HashDoS
//! protection, so a single multiply-xorshift round (SplitMix64 finalizer)
//! suffices.

use std::collections::HashMap;
use std::hash::{BuildHasher, Hasher};

/// Hasher state: the mixed key.
#[derive(Default, Clone, Copy)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // generic fallback (not on the hot path)
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001B3);
        }
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        // SplitMix64 finalizer: full avalanche in 3 ops
        let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        self.0 = z ^ (z >> 31);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

/// `BuildHasher` for [`IdHasher`].
#[derive(Default, Clone, Copy)]
pub struct BuildIdHasher;

impl BuildHasher for BuildIdHasher {
    type Hasher = IdHasher;
    #[inline]
    fn build_hasher(&self) -> IdHasher {
        IdHasher::default()
    }
}

/// A `HashMap` keyed by `u64` ids with the fast hasher.
pub type IdHashMap<V> = HashMap<u64, V, BuildIdHasher>;

/// A `HashSet` of `u64` ids with the fast hasher (e.g. the simulator's
/// reusable started/rejected removal scratch).
pub type IdHashSet = std::collections::HashSet<u64, BuildIdHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_like_hashmap() {
        let mut m: IdHashMap<&str> = IdHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, "x");
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert!(m.contains_key(&i));
            assert!(m.remove(&i).is_some());
        }
        assert!(m.is_empty());
    }

    #[test]
    fn avalanche_differs_for_sequential_keys() {
        let h = |x: u64| {
            let mut hh = IdHasher::default();
            hh.write_u64(x);
            hh.finish()
        };
        // sequential ids land in different buckets (high bits differ)
        let a = h(1) >> 56;
        let b = h(2) >> 56;
        let c = h(3) >> 56;
        assert!(!(a == b && b == c), "no avalanche: {a} {b} {c}");
    }
}
