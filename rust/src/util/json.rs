//! A small, dependency-free JSON parser and serializer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); objects preserve deterministic key order via
//! `BTreeMap`. Used for system-configuration files (Figure 7) and any other
//! structured I/O.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing characters at byte {}", p.pos);
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Encode an `f64` as its exact bit pattern, 16 lowercase hex digits.
///
/// Snapshot files (DESIGN.md §Event log & replay) must round-trip floats
/// *bit-exactly* — including `-0.0`, subnormals and values whose shortest
/// decimal form would re-parse to a neighbouring bit pattern — so they store
/// every float through this encoding rather than as a JSON number.
pub fn f64_to_hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Decode an [`f64_to_hex`] string back to the exact `f64`.
pub fn f64_from_hex(s: &str) -> anyhow::Result<f64> {
    anyhow::ensure!(s.len() == 16, "expected 16 hex digits, got {:?}", s);
    let bits = u64::from_str_radix(s, 16)
        .map_err(|e| anyhow::anyhow!("bad f64 hex {s:?}: {e}"))?;
    Ok(f64::from_bits(bits))
}

/// Encode a `u64` as 16 lowercase hex digits (snapshot format; matches the
/// campaign store's `run_seed` convention).
pub fn u64_to_hex(v: u64) -> String {
    format!("{v:016x}")
}

/// Decode a [`u64_to_hex`] string.
pub fn u64_from_hex(s: &str) -> anyhow::Result<u64> {
    u64::from_str_radix(s, 16).map_err(|e| anyhow::anyhow!("bad u64 hex {s:?}: {e}"))
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(n * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {text:?}: {e}"))?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            anyhow::ensure!(
                                self.pos + 4 < self.bytes.len(),
                                "truncated \\u escape"
                            );
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow::anyhow!("bad \\u escape {hex:?}"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 run
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']' (found {:?})", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}' (found {:?})", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = "line1\nline2\t\"quoted\" \\ back ünïcode \u{1}";
        let mut s = String::new();
        write_escaped(&mut s, original);
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.as_str().unwrap(), original);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str().unwrap(), "A");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2", "{'a': 1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn serializer_roundtrip() {
        let text = r#"{"groups":{"g":{"core":4,"mem":1024}},"name":"Seth","n":[1,2.5,true,null]}"#;
        let v = Json::parse(text).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n"));
    }

    #[test]
    fn numeric_accessors() {
        let v = Json::parse(r#"{"i": 7, "f": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(v.get("i").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("neg").unwrap().as_u64(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap().to_string_compact(), "[]");
        assert_eq!(Json::parse("{}").unwrap().to_string_pretty(), "{}");
    }

    #[test]
    fn f64_hex_roundtrips_bit_exactly() {
        for v in [0.0, -0.0, 1.5, -2.75e-300, f64::MIN_POSITIVE, f64::INFINITY, 280.0] {
            let hex = f64_to_hex(v);
            assert_eq!(hex.len(), 16);
            let back = f64_from_hex(&hex).unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "bits must survive for {v}");
        }
        let nan = f64_from_hex(&f64_to_hex(f64::NAN)).unwrap();
        assert!(nan.is_nan());
        assert!(f64_from_hex("zz").is_err());
        assert!(f64_from_hex("0123").is_err());
    }

    #[test]
    fn u64_hex_roundtrips() {
        for v in [0u64, 1, u64::MAX, 0xdead_beef_0102_0304] {
            assert_eq!(u64_from_hex(&u64_to_hex(v)).unwrap(), v);
        }
        assert!(u64_from_hex("not hex").is_err());
    }

    #[test]
    fn deep_nesting() {
        let mut text = String::new();
        for _ in 0..100 {
            text.push('[');
        }
        text.push('1');
        for _ in 0..100 {
            text.push(']');
        }
        assert!(Json::parse(&text).is_ok());
    }
}
