//! In-repo substrates replacing ecosystem crates unavailable in the offline
//! build: a JSON parser/serializer ([`json`]) and a CLI argument parser
//! ([`args`]).

pub mod args;
pub mod idhash;
pub mod json;
