//! In-repo substrates replacing ecosystem crates unavailable in the offline
//! build: a JSON parser/serializer ([`json`]), a CLI argument parser
//! ([`args`]), and the FNV-1a hash shared by spec identity and the
//! comparator's bootstrap seeding.

pub mod args;
pub mod idhash;
pub mod json;

/// FNV-1a 64 over raw bytes: the stable content hash behind
/// [`crate::campaign::CampaignSpec::spec_hash`] and the campaign
/// comparator's per-pairing bootstrap seeds. One implementation for both,
/// so "seeded from the spec identity" can never silently diverge.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(super::fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(super::fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
