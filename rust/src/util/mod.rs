//! In-repo substrates replacing ecosystem crates unavailable in the offline
//! build: a JSON parser/serializer ([`json`]), a CLI argument parser
//! ([`args`]), and the FNV-1a hash shared by spec identity and the
//! comparator's bootstrap seeding.

pub mod args;
pub mod idhash;
pub mod json;

/// FNV-1a 64 over raw bytes: the stable content hash behind
/// [`crate::campaign::CampaignSpec::spec_hash`] and the campaign
/// comparator's per-pairing bootstrap seeds. One implementation for both,
/// so "seeded from the spec identity" can never silently diverge.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64 finalizer: full-avalanche 64-bit mixing. One implementation
/// for everything that derives keys from the spec identity — run seeds and
/// scenario seeds ([`crate::campaign::matrix`]), the comparator's
/// bootstrap seeds, and the `_RND` schedulers' tie-break hashes.
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(super::fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(super::fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
