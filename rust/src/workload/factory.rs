//! The job factory: raw SWF records → synthetic [`Job`]s.
//!
//! Mirrors AccaSim's *job factory* subcomponent (§3): it normalizes raw
//! records, fills in missing attributes (e.g. duration estimates) and maps
//! SWF's processor/memory request onto the simulator's indexed slot model.

use super::job::{Job, JobId};
use super::swf::SwfFields;
use crate::config::SysConfig;

/// Configuration of the SWF → [`Job`] mapping.
#[derive(Debug, Clone)]
pub struct FactoryConfig {
    /// Resource type that SWF "processors" map to (default `"core"`).
    pub proc_type: String,
    /// Resource type that SWF per-processor memory maps to (default `"mem"`),
    /// `None` to ignore memory requests.
    pub mem_type: Option<String>,
    /// When the trace has no requested-time field, estimate duration as
    /// `duration * overestimate_factor` (users overestimate; a factor of 2 is
    /// the classic observation). Set to 1.0 for exact estimates.
    pub overestimate_factor: f64,
    /// Clamp slot counts to the system's largest node capacity when a record
    /// requests more processors than exist (mirrors AccaSim preprocessing).
    pub clamp_to_system: bool,
}

impl Default for FactoryConfig {
    fn default() -> Self {
        FactoryConfig {
            proc_type: "core".to_string(),
            mem_type: Some("mem".to_string()),
            overestimate_factor: 2.0,
            clamp_to_system: true,
        }
    }
}

/// Builds [`Job`]s from raw records against a specific system configuration.
#[derive(Debug)]
pub struct JobFactory {
    cfg: FactoryConfig,
    /// Ordered resource types of the target system.
    resource_types: Vec<String>,
    proc_idx: usize,
    mem_idx: Option<usize>,
    /// Total processor capacity of the system (for clamping).
    total_procs: u64,
    /// Jobs rejected as unrunnable (zero slots after normalization, or
    /// requests exceeding the whole machine with clamping disabled).
    pub rejected: u64,
    next_synthetic_id: JobId,
}

impl JobFactory {
    /// Create a factory for a system configuration.
    pub fn new(sys: &SysConfig, cfg: FactoryConfig) -> anyhow::Result<Self> {
        let resource_types = sys.resource_types();
        let proc_idx = resource_types
            .iter()
            .position(|t| *t == cfg.proc_type)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "factory proc_type {:?} not among system resource types {:?}",
                    cfg.proc_type,
                    resource_types
                )
            })?;
        let mem_idx = match &cfg.mem_type {
            Some(m) => resource_types.iter().position(|t| t == m),
            None => None,
        };
        let total_procs = sys.total_of(&cfg.proc_type);
        Ok(JobFactory {
            cfg,
            resource_types,
            proc_idx,
            mem_idx,
            total_procs,
            rejected: 0,
            next_synthetic_id: 1,
        })
    }

    /// The resource-type order jobs produced by this factory are indexed by.
    pub fn resource_types(&self) -> &[String] {
        &self.resource_types
    }

    /// Convert one raw record. Returns `None` when the record is unrunnable
    /// on this system and was rejected (counted in [`JobFactory::rejected`]).
    pub fn build(&mut self, f: &SwfFields) -> Option<Job> {
        // --- identification ---------------------------------------------
        let id = if f.job_number > 0 {
            f.job_number as JobId
        } else {
            let id = self.next_synthetic_id;
            self.next_synthetic_id += 1;
            id
        };

        // --- timing -------------------------------------------------------
        let submit = f.submit_time.max(0) as u64;
        let duration = f.run_time.max(0) as u64;
        let req_time = if f.requested_time > 0 {
            f.requested_time as u64
        } else {
            // duration-estimation attribute (§3): synthesize an overestimate
            ((duration as f64 * self.cfg.overestimate_factor).ceil() as u64).max(1)
        };

        // --- resource request ------------------------------------------
        let procs_raw = if f.requested_procs > 0 {
            f.requested_procs
        } else if f.allocated_procs > 0 {
            f.allocated_procs
        } else {
            1
        } as u64;
        let procs = if procs_raw > self.total_procs {
            if self.cfg.clamp_to_system {
                self.total_procs
            } else {
                self.rejected += 1;
                return None;
            }
        } else {
            procs_raw
        };
        if procs == 0 {
            self.rejected += 1;
            return None;
        }

        let mut per_slot = vec![0u64; self.resource_types.len()];
        per_slot[self.proc_idx] = 1;
        if let Some(mi) = self.mem_idx {
            // SWF memory is KB per processor; our configs express memory in
            // MB per node, so scale down (and keep at least 1 MB if any
            // memory was requested).
            let kb_per_proc = if f.requested_memory > 0 {
                f.requested_memory
            } else if f.used_memory > 0 {
                f.used_memory
            } else {
                0
            } as u64;
            per_slot[mi] = kb_per_proc / 1024 + u64::from(kb_per_proc % 1024 != 0);
        }

        Some(Job {
            id,
            submit,
            duration,
            req_time,
            slots: procs.min(u32::MAX as u64) as u32,
            per_slot,
            user: f.user_id.max(0) as u32,
            app: f.app_id.max(0) as u32,
            status: f.status as i32,
            // interned by the simulator at submission, against the run's
            // resource manager (the factory has no shape table)
            shape: crate::resources::ShapeId::UNSET,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::swf::parse_swf_line;

    fn sys() -> SysConfig {
        SysConfig::homogeneous("t", 4, &[("core", 4), ("mem", 1024)], 0)
    }

    fn factory() -> JobFactory {
        JobFactory::new(&sys(), FactoryConfig::default()).unwrap()
    }

    #[test]
    fn basic_mapping() {
        let mut fac = factory();
        let f = parse_swf_line("1 100 -1 600 -1 -1 -1 4 1200 2048 1 9 1 2 1 1 -1 -1").unwrap();
        let j = fac.build(&f).unwrap();
        assert_eq!(j.id, 1);
        assert_eq!(j.submit, 100);
        assert_eq!(j.duration, 600);
        assert_eq!(j.req_time, 1200);
        assert_eq!(j.slots, 4);
        // core idx 0, mem idx 1 (lexicographic)
        assert_eq!(j.per_slot, vec![1, 2]); // 2048 KB -> 2 MB per slot
        assert_eq!(j.user, 9);
    }

    #[test]
    fn missing_estimate_synthesized() {
        let mut fac = factory();
        let f = parse_swf_line("2 0 -1 100 -1 -1 -1 1 -1 -1 1 1 1 1 1 1 -1 -1").unwrap();
        let j = fac.build(&f).unwrap();
        assert_eq!(j.req_time, 200); // 2x overestimate
    }

    #[test]
    fn fallback_to_allocated_procs() {
        let mut fac = factory();
        let f = parse_swf_line("3 0 -1 10 3 -1 -1 -1 -1 -1 1 1 1 1 1 1 -1 -1").unwrap();
        assert_eq!(fac.build(&f).unwrap().slots, 3);
    }

    #[test]
    fn oversized_request_clamped() {
        let mut fac = factory();
        // 64 procs > 16 total
        let f = parse_swf_line("4 0 -1 10 -1 -1 -1 64 10 -1 1 1 1 1 1 1 -1 -1").unwrap();
        assert_eq!(fac.build(&f).unwrap().slots, 16);
        assert_eq!(fac.rejected, 0);
    }

    #[test]
    fn oversized_request_rejected_without_clamp() {
        let mut fac = JobFactory::new(
            &sys(),
            FactoryConfig { clamp_to_system: false, ..FactoryConfig::default() },
        )
        .unwrap();
        let f = parse_swf_line("4 0 -1 10 -1 -1 -1 64 10 -1 1 1 1 1 1 1 -1 -1").unwrap();
        assert!(fac.build(&f).is_none());
        assert_eq!(fac.rejected, 1);
    }

    #[test]
    fn mem_kb_rounds_up() {
        let mut fac = factory();
        let f = parse_swf_line("5 0 -1 10 -1 -1 -1 1 10 1 1 1 1 1 1 1 -1 -1").unwrap();
        let j = fac.build(&f).unwrap();
        assert_eq!(j.per_slot[1], 1); // 1 KB rounds up to 1 MB
    }

    #[test]
    fn synthetic_ids_for_unnumbered() {
        let mut fac = factory();
        let f = parse_swf_line("-1 0 -1 10 -1 -1 -1 1 10 -1 1 1 1 1 1 1 -1 -1").unwrap();
        assert_eq!(fac.build(&f).unwrap().id, 1);
        assert_eq!(fac.build(&f).unwrap().id, 2);
    }

    #[test]
    fn unknown_proc_type_errors() {
        let err = JobFactory::new(
            &sys(),
            FactoryConfig { proc_type: "gpu".to_string(), ..FactoryConfig::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("proc_type"));
    }
}
