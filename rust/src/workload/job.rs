//! The synthetic job model.

use crate::resources::ShapeId;

/// Identifier of a job inside one simulation (the SWF job number).
pub type JobId = u64;

/// Artificial job life-cycle states (§3, *event manager*).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobState {
    /// Known to the simulator but its submission time has not been reached.
    Loaded,
    /// Submitted and waiting in the queue.
    Queued,
    /// Dispatched and occupying resources.
    Running,
    /// Finished; resources released. Completed jobs are retired from memory.
    Completed,
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            JobState::Loaded => "loaded",
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
        };
        f.write_str(s)
    }
}

/// A synthetic job.
///
/// Resource requests use the *slot* model: a job asks for `slots` processing
/// slots, each slot carrying `per_slot[r]` units of resource type `r`
/// (resource types are indexed in the order of
/// [`crate::config::SysConfig::resource_types`]). A slot is the schedulable
/// grain — for an SWF trace a slot is one requested processor together with
/// its proportional share of requested memory. Slots of one job may be placed
/// on different nodes, which is how jobs span nodes while still permitting
/// many small jobs to share one node (the paper's Seth case study models the
/// system "made of cores instead of processors" for exactly this reason).
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    /// SWF job number.
    pub id: JobId,
    /// Absolute submission time `T_sb` (epoch seconds).
    pub submit: u64,
    /// Actual duration (seconds). Known only to the event manager; the
    /// dispatcher must rely on `req_time` (§3, *dispatcher*).
    pub duration: u64,
    /// User-requested wall time (the duration *estimation* dispatchers see).
    pub req_time: u64,
    /// Number of processing slots requested (≥ 1).
    pub slots: u32,
    /// Per-slot request for each resource type, indexed by the system's
    /// resource-type order.
    pub per_slot: Vec<u64>,
    /// SWF user id (for per-user statistics; 0 when absent).
    pub user: u32,
    /// SWF executable/application id (0 when absent).
    pub app: u32,
    /// SWF status field (-1 when absent).
    pub status: i32,
    /// Interned handle of `per_slot` in the resource manager's shape table
    /// (DESIGN.md §Perf). The simulator interns it at submission so
    /// availability queries on the dispatch hot path are index lookups
    /// instead of per-node scans; hand-built jobs default to
    /// [`ShapeId::UNSET`] and transparently use the full-scan path. Ids are
    /// only meaningful to the [`crate::resources::ResourceManager`] that
    /// issued them — stale ids are detected by vector comparison and
    /// demoted to the naive path.
    pub shape: ShapeId,
}

impl Job {
    /// Completion time if started at `start`.
    #[inline]
    pub fn completion_at(&self, start: u64) -> u64 {
        start + self.duration
    }

    /// Dispatcher-visible estimated completion if started at `start`.
    #[inline]
    pub fn estimated_completion_at(&self, start: u64) -> u64 {
        start + self.req_time.max(1)
    }

    /// Total request of resource type `r` across all slots.
    #[inline]
    pub fn total_request(&self, r: usize) -> u64 {
        self.per_slot.get(r).copied().unwrap_or(0) * self.slots as u64
    }

    /// Slowdown given waiting time `wait`:
    /// `(T_w + T_r) / T_r` with `T_r` clamped to ≥ 1 s (the usual bounded
    /// variant guard against zero-length jobs).
    #[inline]
    pub fn slowdown(&self, wait: u64) -> f64 {
        let tr = self.duration.max(1) as f64;
        (wait as f64 + tr) / tr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        Job {
            id: 7,
            submit: 100,
            duration: 50,
            req_time: 80,
            slots: 4,
            per_slot: vec![1, 256],
            user: 3,
            app: 9,
            status: 1,
            shape: ShapeId::UNSET,
        }
    }

    #[test]
    fn completion_and_estimate() {
        let j = job();
        assert_eq!(j.completion_at(200), 250);
        assert_eq!(j.estimated_completion_at(200), 280);
    }

    #[test]
    fn total_request_scales_by_slots() {
        let j = job();
        assert_eq!(j.total_request(0), 4);
        assert_eq!(j.total_request(1), 1024);
        assert_eq!(j.total_request(2), 0); // out-of-range type
    }

    #[test]
    fn slowdown_definition() {
        let j = job();
        assert!((j.slowdown(0) - 1.0).abs() < 1e-12);
        assert!((j.slowdown(50) - 2.0).abs() < 1e-12);
        assert!((j.slowdown(100) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn slowdown_zero_duration_guard() {
        let mut j = job();
        j.duration = 0;
        assert!((j.slowdown(10) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn state_display() {
        assert_eq!(JobState::Loaded.to_string(), "loaded");
        assert_eq!(JobState::Queued.to_string(), "queued");
        assert_eq!(JobState::Running.to_string(), "running");
        assert_eq!(JobState::Completed.to_string(), "completed");
    }
}
