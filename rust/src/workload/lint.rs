//! Workload dataset linting — the preprocessing/cleaning step of §6.2
//! ("removes jobs with incomplete or erroneous data") surfaced as a
//! diagnosable report instead of silent skips.

use super::swf::SwfFields;
use super::Reader;

/// One category of workload issue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LintIssue {
    /// Line could not be parsed at all (counted by the reader).
    Malformed,
    /// Negative or missing run time.
    BadRunTime,
    /// No processor request at all (neither requested nor allocated).
    NoProcessors,
    /// Submission time goes backwards relative to the previous record.
    NonMonotonicSubmit,
    /// Requested time smaller than actual run time (broken estimate).
    EstimateBelowRuntime,
    /// Duplicate job number.
    DuplicateId,
}

impl LintIssue {
    /// Human-readable category label (also the report's grouping key).
    pub fn describe(&self) -> &'static str {
        match self {
            LintIssue::Malformed => "unparseable line",
            LintIssue::BadRunTime => "missing/negative run time",
            LintIssue::NoProcessors => "no processor request",
            LintIssue::NonMonotonicSubmit => "submission time decreases",
            LintIssue::EstimateBelowRuntime => "requested time < run time",
            LintIssue::DuplicateId => "duplicate job number",
        }
    }
}

/// Lint report over a workload source.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Parseable records examined.
    pub records: u64,
    /// Issue → occurrence count.
    pub issues: std::collections::BTreeMap<&'static str, u64>,
    /// First few offending job numbers per issue (for digging in).
    pub examples: std::collections::BTreeMap<&'static str, Vec<i64>>,
    /// Earliest submission time seen (0 for an empty workload).
    pub first_submit: i64,
    /// Latest submission time seen.
    pub last_submit: i64,
}

impl LintReport {
    fn record(&mut self, issue: LintIssue, job: i64) {
        let key = issue.describe();
        *self.issues.entry(key).or_default() += 1;
        let ex = self.examples.entry(key).or_default();
        if ex.len() < 5 {
            ex.push(job);
        }
    }

    /// Total issue count.
    pub fn total_issues(&self) -> u64 {
        self.issues.values().sum()
    }

    /// Human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "{} records, {} issue(s); span [{}, {}]\n",
            self.records,
            self.total_issues(),
            self.first_submit,
            self.last_submit
        );
        for (issue, count) in &self.issues {
            out.push_str(&format!(
                "  {count:>8} × {issue} (e.g. jobs {:?})\n",
                self.examples[issue]
            ));
        }
        out
    }
}

/// Lint every record of a reader.
pub fn lint<R: Reader>(reader: &mut R) -> LintReport {
    let mut report = LintReport { first_submit: i64::MAX, ..Default::default() };
    let mut prev_submit = i64::MIN;
    let mut seen_ids = std::collections::HashSet::new();
    while let Some(rec) = reader.next_record() {
        let Ok(f) = rec else {
            report.record(LintIssue::Malformed, -1);
            continue;
        };
        report.records += 1;
        report.first_submit = report.first_submit.min(f.submit_time);
        report.last_submit = report.last_submit.max(f.submit_time);
        check_record(&f, prev_submit, &mut seen_ids, &mut report);
        prev_submit = f.submit_time;
    }
    if report.records == 0 {
        report.first_submit = 0;
    }
    report
}

fn check_record(
    f: &SwfFields,
    prev_submit: i64,
    seen: &mut std::collections::HashSet<i64>,
    report: &mut LintReport,
) {
    if f.run_time < 0 {
        report.record(LintIssue::BadRunTime, f.job_number);
    }
    if f.requested_procs <= 0 && f.allocated_procs <= 0 {
        report.record(LintIssue::NoProcessors, f.job_number);
    }
    if f.submit_time < prev_submit {
        report.record(LintIssue::NonMonotonicSubmit, f.job_number);
    }
    if f.requested_time > 0 && f.run_time > 0 && f.requested_time < f.run_time {
        report.record(LintIssue::EstimateBelowRuntime, f.job_number);
    }
    if f.job_number >= 0 && !seen.insert(f.job_number) {
        report.record(LintIssue::DuplicateId, f.job_number);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil as tempfile;
    use crate::workload::SwfReader;
    use std::io::Write;

    fn lint_text(lines: &[&str]) -> LintReport {
        let dir = tempfile::tempdir().unwrap();
        let p = dir.path().join("w.swf");
        let mut f = std::fs::File::create(&p).unwrap();
        for l in lines {
            writeln!(f, "{l}").unwrap();
        }
        drop(f);
        let mut r = SwfReader::open(&p).unwrap();
        lint(&mut r)
    }

    #[test]
    fn clean_workload_no_issues() {
        let rep = lint_text(&[
            "1 0 -1 60 2 -1 -1 2 120 -1 1 1 1 1 1 1 -1 -1",
            "2 5 -1 30 1 -1 -1 1 60 -1 1 1 1 1 1 1 -1 -1",
        ]);
        assert_eq!(rep.records, 2);
        assert_eq!(rep.total_issues(), 0);
        assert_eq!(rep.first_submit, 0);
        assert_eq!(rep.last_submit, 5);
    }

    #[test]
    fn detects_each_issue() {
        let rep = lint_text(&[
            "1 10 -1 -1 2 -1 -1 2 120 -1 1 1 1 1 1 1 -1 -1", // bad runtime
            "2 20 -1 60 -1 -1 -1 -1 120 -1 1 1 1 1 1 1 -1 -1", // no procs
            "3 5 -1 60 2 -1 -1 2 120 -1 1 1 1 1 1 1 -1 -1",  // non-monotonic
            "3 30 -1 60 2 -1 -1 2 10 -1 1 1 1 1 1 1 -1 -1",  // dup id + bad estimate
        ]);
        assert_eq!(rep.records, 4);
        assert_eq!(rep.issues["missing/negative run time"], 1);
        assert_eq!(rep.issues["no processor request"], 1);
        assert_eq!(rep.issues["submission time decreases"], 1);
        assert_eq!(rep.issues["requested time < run time"], 1);
        assert_eq!(rep.issues["duplicate job number"], 1);
        let rendered = rep.render();
        assert!(rendered.contains("duplicate job number"));
    }

    #[test]
    fn synthesized_traces_are_clean() {
        let dir = tempfile::tempdir().unwrap();
        let p = dir.path().join("seth.swf");
        crate::traces::SETH.synthesize(&p, 0.002, 1).unwrap();
        let mut r = SwfReader::open(&p).unwrap();
        let rep = lint(&mut r);
        assert_eq!(rep.records, 406);
        assert_eq!(rep.total_issues(), 0, "{}", rep.render());
    }

    #[test]
    fn empty_workload() {
        let rep = lint_text(&["; just a header"]);
        assert_eq!(rep.records, 0);
        assert_eq!(rep.first_submit, 0);
    }
}
