//! Workload substrate: job model, Standard Workload Format (SWF) I/O and the
//! job factory (the paper's *job submission* component, §3).
//!
//! The default input format is SWF (Feitelson et al. [12]); any other source
//! can be plugged in by implementing [`Reader`], mirroring AccaSim's abstract
//! `Reader` class. Reading is *incremental*: [`SwfReader`] is an iterator over
//! jobs, so the simulator only materializes jobs that are close to submission
//! (the paper's key scalability mechanism, contrasted with Batsim/Alea's eager
//! loading in Table 1).

mod factory;
mod job;
pub mod lint;
mod swf;

pub use factory::{FactoryConfig, JobFactory};
pub use job::{Job, JobId, JobState};
pub use lint::{lint, LintIssue, LintReport};
pub use swf::{SwfFields, SwfReader, SwfWriter, parse_swf_line, SWF_FIELD_COUNT};

/// Abstract workload source, mirroring AccaSim's `Reader` base class.
///
/// A reader yields raw [`SwfFields`] records in submission order; the
/// [`JobFactory`] turns them into synthetic [`Job`]s for the simulator.
pub trait Reader {
    /// Pull the next raw record, `None` at end of workload.
    fn next_record(&mut self) -> Option<anyhow::Result<SwfFields>>;
}

/// Abstract workload sink, mirroring AccaSim's `WorkloadWriter` base class.
pub trait WorkloadWriter {
    /// Append one job record.
    fn write_job(&mut self, fields: &SwfFields) -> anyhow::Result<()>;
    /// Flush any buffered output.
    fn finish(&mut self) -> anyhow::Result<()>;
}
