//! Standard Workload Format (SWF) parsing and writing.
//!
//! SWF (Feitelson, Tsafrir, Krakov [12]) is a line-oriented text format:
//! comment/header lines start with `;`, data lines carry 18 whitespace-
//! separated integer fields:
//!
//! ```text
//!  1 job number          7 used memory (KB/proc)   13 group id
//!  2 submit time         8 requested processors    14 executable (app) id
//!  3 wait time           9 requested time          15 queue id
//!  4 run time           10 requested memory        16 partition id
//!  5 allocated procs    11 status                  17 preceding job
//!  6 avg cpu time       12 user id                 18 think time
//! ```
//!
//! `-1` means "unknown" for any field. The parser is tolerant: missing
//! trailing fields are treated as `-1`, and malformed lines produce a
//! descriptive error carrying the line number (the simulator skips them and
//! counts them, mirroring the preprocessing the paper describes in §6.2).

use super::{Reader, WorkloadWriter};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Number of fields in a full SWF record.
pub const SWF_FIELD_COUNT: usize = 18;

/// One raw SWF record. Field names follow the SWF standard; all are i64 with
/// `-1` meaning unknown.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SwfFields {
    /// Field 1: job number (unique per trace).
    pub job_number: i64,
    /// Field 2: submission time (seconds since trace start).
    pub submit_time: i64,
    /// Field 3: recorded waiting time (seconds).
    pub wait_time: i64,
    /// Field 4: actual run time (seconds).
    pub run_time: i64,
    /// Field 5: processors actually allocated.
    pub allocated_procs: i64,
    /// Field 6: average CPU time per processor (seconds).
    pub avg_cpu_time: i64,
    /// Field 7: memory actually used (KB per processor).
    pub used_memory: i64,
    /// Field 8: processors requested.
    pub requested_procs: i64,
    /// Field 9: wall time requested (seconds — the dispatcher's estimate).
    pub requested_time: i64,
    /// Field 10: memory requested (KB per processor).
    pub requested_memory: i64,
    /// Field 11: completion status code.
    pub status: i64,
    /// Field 12: submitting user id.
    pub user_id: i64,
    /// Field 13: submitting group id.
    pub group_id: i64,
    /// Field 14: executable/application id.
    pub app_id: i64,
    /// Field 15: queue id.
    pub queue_id: i64,
    /// Field 16: partition id.
    pub partition_id: i64,
    /// Field 17: job this one waits on (workflow dependency).
    pub preceding_job: i64,
    /// Field 18: think time after the preceding job (seconds).
    pub think_time: i64,
}

impl SwfFields {
    /// Render as one SWF data line.
    pub fn to_line(&self) -> String {
        format!(
            "{} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {}",
            self.job_number,
            self.submit_time,
            self.wait_time,
            self.run_time,
            self.allocated_procs,
            self.avg_cpu_time,
            self.used_memory,
            self.requested_procs,
            self.requested_time,
            self.requested_memory,
            self.status,
            self.user_id,
            self.group_id,
            self.app_id,
            self.queue_id,
            self.partition_id,
            self.preceding_job,
            self.think_time
        )
    }
}

/// Fast-path integer parse (the simulator spends ~15% of a Table-1 run in
/// SWF parsing; `str::parse` + error plumbing dominated it — see
/// EXPERIMENTS.md §Perf). Falls back to float parsing for the rare archives
/// carrying fractional fields.
#[inline]
fn parse_swf_num(tok: &str) -> Option<i64> {
    let b = tok.as_bytes();
    let (neg, digits) = match b.first()? {
        b'-' => (true, &b[1..]),
        b'+' => (false, &b[1..]),
        _ => (false, b),
    };
    if digits.is_empty() {
        return None;
    }
    let mut acc: i64 = 0;
    for &c in digits {
        if !c.is_ascii_digit() {
            // float field (e.g. "59.5") — slow path
            return tok.parse::<f64>().ok().map(|f| f as i64);
        }
        acc = acc.checked_mul(10)?.checked_add((c - b'0') as i64)?;
    }
    Some(if neg { -acc } else { acc })
}

/// Parse one SWF data line (must not be a comment line).
pub fn parse_swf_line(line: &str) -> anyhow::Result<SwfFields> {
    let mut vals = [-1i64; SWF_FIELD_COUNT];
    let mut n = 0;
    for tok in line.split_ascii_whitespace() {
        if n >= SWF_FIELD_COUNT {
            break; // tolerate trailing junk
        }
        vals[n] = parse_swf_num(tok)
            .ok_or_else(|| anyhow::anyhow!("non-numeric SWF field {:?}", tok))?;
        n += 1;
    }
    if n < 4 {
        anyhow::bail!("SWF line has only {n} fields (need at least job/submit/wait/run)");
    }
    Ok(SwfFields {
        job_number: vals[0],
        submit_time: vals[1],
        wait_time: vals[2],
        run_time: vals[3],
        allocated_procs: vals[4],
        avg_cpu_time: vals[5],
        used_memory: vals[6],
        requested_procs: vals[7],
        requested_time: vals[8],
        requested_memory: vals[9],
        status: vals[10],
        user_id: vals[11],
        group_id: vals[12],
        app_id: vals[13],
        queue_id: vals[14],
        partition_id: vals[15],
        preceding_job: vals[16],
        think_time: vals[17],
    })
}

/// Streaming SWF reader (the default [`Reader`]); iterates records in file
/// order without materializing the workload. Uses one reusable line buffer
/// — `Lines<_>` allocates a fresh `String` per line, which showed up in the
/// Table-1 profiles (EXPERIMENTS.md §Perf).
pub struct SwfReader {
    input: BufReader<std::fs::File>,
    buf: String,
    line_no: usize,
    /// Header comment lines seen so far (`;` lines).
    pub header: Vec<String>,
    /// Count of malformed data lines skipped.
    pub skipped: usize,
}

impl SwfReader {
    /// Open an SWF file for streaming.
    pub fn open<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let f = std::fs::File::open(path.as_ref()).map_err(|e| {
            anyhow::anyhow!("opening workload {}: {e}", path.as_ref().display())
        })?;
        Ok(SwfReader {
            input: BufReader::with_capacity(1 << 16, f),
            buf: String::with_capacity(256),
            line_no: 0,
            header: Vec::new(),
            skipped: 0,
        })
    }
}

impl Reader for SwfReader {
    fn next_record(&mut self) -> Option<anyhow::Result<SwfFields>> {
        loop {
            self.buf.clear();
            match self.input.read_line(&mut self.buf) {
                Ok(0) => return None,
                Ok(_) => {}
                Err(e) => return Some(Err(e.into())),
            }
            self.line_no += 1;
            let trimmed = self.buf.trim();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(h) = trimmed.strip_prefix(';') {
                self.header.push(h.trim().to_string());
                continue;
            }
            match parse_swf_line(trimmed) {
                Ok(f) => return Some(Ok(f)),
                Err(_) => {
                    // Preprocessing: skip malformed lines, keep count (§6.2).
                    self.skipped += 1;
                    continue;
                }
            }
        }
    }
}

impl Iterator for SwfReader {
    type Item = anyhow::Result<SwfFields>;
    fn next(&mut self) -> Option<Self::Item> {
        self.next_record()
    }
}

/// Buffered SWF writer (the default [`WorkloadWriter`]).
pub struct SwfWriter {
    out: BufWriter<std::fs::File>,
    records: u64,
}

impl SwfWriter {
    /// Create/truncate an SWF file, writing the given header comments.
    pub fn create<P: AsRef<Path>>(path: P, header: &[String]) -> anyhow::Result<Self> {
        let f = std::fs::File::create(path)?;
        let mut out = BufWriter::with_capacity(1 << 16, f);
        for h in header {
            writeln!(out, "; {h}")?;
        }
        Ok(SwfWriter { out, records: 0 })
    }

    /// Number of records written so far.
    pub fn records(&self) -> u64 {
        self.records
    }
}

impl WorkloadWriter for SwfWriter {
    fn write_job(&mut self, fields: &SwfFields) -> anyhow::Result<()> {
        writeln!(self.out, "{}", fields.to_line())?;
        self.records += 1;
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[allow(unused_imports)]
    use crate::testutil as tempfile;

    #[test]
    fn parse_full_line() {
        let f = parse_swf_line("1 0 10 3600 4 -1 1024 4 7200 1024 1 5 2 3 1 1 -1 -1").unwrap();
        assert_eq!(f.job_number, 1);
        assert_eq!(f.submit_time, 0);
        assert_eq!(f.run_time, 3600);
        assert_eq!(f.requested_procs, 4);
        assert_eq!(f.requested_time, 7200);
        assert_eq!(f.user_id, 5);
        assert_eq!(f.think_time, -1);
    }

    #[test]
    fn parse_short_line_pads_unknown() {
        let f = parse_swf_line("2 5 -1 60").unwrap();
        assert_eq!(f.job_number, 2);
        assert_eq!(f.run_time, 60);
        assert_eq!(f.requested_procs, -1);
        assert_eq!(f.status, -1);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_swf_line("a b c d").is_err());
        assert!(parse_swf_line("1 2").is_err());
    }

    #[test]
    fn parse_accepts_float_fields() {
        // some archives carry float avg-cpu-time
        let f = parse_swf_line("1 0 0 60 4 59.5 -1 4 60 -1 1 1 1 1 1 1 -1 -1").unwrap();
        assert_eq!(f.avg_cpu_time, 59);
    }

    #[test]
    fn line_roundtrip() {
        let f = parse_swf_line("9 100 2 30 1 -1 512 1 60 512 1 7 8 9 2 1 -1 -1").unwrap();
        let f2 = parse_swf_line(&f.to_line()).unwrap();
        assert_eq!(f, f2);
    }

    #[test]
    fn reader_streams_and_collects_header() {
        let dir = tempfile::tempdir().unwrap();
        let p = dir.path().join("w.swf");
        let mut fh = std::fs::File::create(&p).unwrap();
        writeln!(fh, "; Version: 2.2").unwrap();
        writeln!(fh, "; MaxNodes: 120").unwrap();
        writeln!(fh).unwrap();
        writeln!(fh, "1 0 -1 60 1 -1 -1 1 120 -1 1 1 1 1 1 1 -1 -1").unwrap();
        writeln!(fh, "this line is broken").unwrap();
        writeln!(fh, "2 5 -1 30 2 -1 -1 2 60 -1 1 1 1 1 1 1 -1 -1").unwrap();
        drop(fh);

        let mut r = SwfReader::open(&p).unwrap();
        let jobs: Vec<_> = (&mut r).map(|x| x.unwrap()).collect();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].job_number, 1);
        assert_eq!(jobs[1].job_number, 2);
        assert_eq!(r.skipped, 1);
        assert_eq!(r.header.len(), 2);
        assert!(r.header[1].contains("MaxNodes"));
    }

    #[test]
    fn writer_then_reader_roundtrip() {
        let dir = tempfile::tempdir().unwrap();
        let p = dir.path().join("w.swf");
        let mut w = SwfWriter::create(&p, &["UnitTime: seconds".to_string()]).unwrap();
        for i in 1..=5i64 {
            let f = SwfFields {
                job_number: i,
                submit_time: i * 10,
                run_time: 60,
                requested_procs: 2,
                requested_time: 100,
                ..Default::default()
            };
            w.write_job(&f).unwrap();
        }
        w.finish().unwrap();
        assert_eq!(w.records(), 5);

        let r = SwfReader::open(&p).unwrap();
        let jobs: Vec<_> = r.map(|x| x.unwrap()).collect();
        assert_eq!(jobs.len(), 5);
        assert_eq!(jobs[4].submit_time, 50);
    }
}
