//! Availability-index invariants (DESIGN.md §Perf).
//!
//! Two families of guarantees:
//!
//! 1. **Oracle equivalence** — after every allocate/release/down/up/intern
//!    step of a randomized sequence, every indexed query (per-node
//!    hostable, feasible enumeration, `can_host`, `can_ever_host`) must
//!    equal a naive full scan recomputed from the free/capacity matrices,
//!    and the hierarchical block/superblock bitmaps must stay consistent
//!    with the per-node hostable counts.
//! 2. **Byte identity** — simulations and whole campaigns executed with the
//!    index disabled (`SimOptions::use_shape_index = false`, the pre-index
//!    code path) or the feasibility bitmaps disabled
//!    (`SimOptions::use_feasible_bitmap = false`, the flat-scan oracle
//!    path) must produce byte-identical outputs: speed must not change
//!    results.

use accasim::config::SysConfig;
use accasim::dispatch::dispatcher_from_label;
use accasim::output::OutputCollector;
use accasim::resources::{hostable_slots_in, Allocation, ResourceManager, ShapeId};
use accasim::rng::Pcg64;
use accasim::sim::{SimOptions, SimOutput, Simulator};
use accasim::testkit::{arb_jobs, check};
use accasim::testutil as tempfile;
use accasim::workload::Job;

fn probe(per_slot: &[u64], shape: ShapeId, slots: u32) -> Job {
    Job {
        id: 0,
        submit: 0,
        duration: 1,
        req_time: 1,
        slots,
        per_slot: per_slot.to_vec(),
        user: 0,
        app: 0,
        status: 1,
        shape,
    }
}

/// Naive oracle: hostable slots of `shape` on `node`, recomputed from the
/// manager's public matrices (the pre-index code path).
fn oracle_hostable(rm: &ResourceManager, node: usize, shape: &[u64]) -> u64 {
    if rm.is_node_down(node) {
        0
    } else {
        hostable_slots_in(rm.node_free(node), shape)
    }
}

fn oracle_total(rm: &ResourceManager, shape: &[u64]) -> u128 {
    (0..rm.num_nodes()).map(|n| oracle_hostable(rm, n, shape) as u128).sum()
}

fn oracle_ever_total(rm: &ResourceManager, shape: &[u64]) -> u128 {
    (0..rm.num_nodes())
        .map(|n| hostable_slots_in(rm.node_capacity(n), shape) as u128)
        .sum()
}

/// Assert every indexed query on `rm` equals the full-scan oracle, for
/// every interned shape.
fn assert_index_matches_oracle(rm: &ResourceManager, shapes: &[(Vec<u64>, ShapeId)]) {
    for (vec, sid) in shapes {
        let total = oracle_total(rm, vec);
        let mut oracle_feasible = Vec::new();
        for n in 0..rm.num_nodes() {
            let expect = oracle_hostable(rm, n, vec);
            assert_eq!(
                rm.shaped_hostable_slots(*sid, n),
                expect,
                "shape {vec:?} node {n}: index diverged from the full scan"
            );
            if expect > 0 {
                oracle_feasible.push(n as u32);
            }
        }
        let mut feasible = Vec::new();
        rm.shaped_feasible_nodes(*sid, &mut feasible);
        assert_eq!(feasible, oracle_feasible, "shape {vec:?}: feasible set diverged");

        // can_host at the boundary: exactly `total` fits, `total + 1` not
        for slots in [1u128, total.max(1), total + 1] {
            let slots = slots.min(u32::MAX as u128) as u32;
            let fast = probe(vec, *sid, slots);
            assert_eq!(
                rm.can_host(&fast),
                total >= slots as u128 && slots > 0,
                "shape {vec:?} slots {slots}: can_host diverged (total {total})"
            );
            assert_eq!(
                rm.can_ever_host(&fast),
                oracle_ever_total(rm, vec) >= slots as u128,
                "shape {vec:?} slots {slots}: can_ever_host diverged"
            );
        }
    }
}

/// Greedy first-fit allocation of `slots` slots of `shape`, straight from
/// the oracle (independent of the allocators under test).
fn oracle_place(rm: &ResourceManager, shape: &[u64], slots: u32) -> Option<Allocation> {
    let mut remaining = slots as u64;
    let mut slices = Vec::new();
    for n in 0..rm.num_nodes() {
        if remaining == 0 {
            break;
        }
        let h = oracle_hostable(rm, n, shape).min(remaining);
        if h > 0 {
            slices.push((n as u32, h as u32));
            remaining -= h;
        }
    }
    (remaining == 0).then_some(Allocation { slices })
}

/// The tentpole property: drive randomized allocate/release/down/up/intern
/// sequences (long enough to force journal compactions) and assert the
/// index equals the naive full-scan oracle after every single step — and
/// that the block/superblock bitmap layers stay consistent with the
/// hostable counts throughout, across compactions, mid-sequence interning
/// and mid-sequence bitmap toggling. Half the cases run with a tiny
/// configured journal limit so the compaction/STALE rebuild path fires
/// constantly even on small systems.
#[test]
fn prop_index_matches_full_scan_oracle() {
    check("availability-index", 0x1DEC5, 30, |rng| {
        let nodes = rng.range_u64(1, 10);
        let sys = SysConfig::homogeneous(
            "idx",
            nodes,
            &[("core", rng.range_u64(1, 8)), ("mem", rng.range_u64(4, 64))],
            0,
        );
        let mut rm = ResourceManager::from_config(&sys);
        if rng.range_u64(0, 1) == 1 {
            // the limit clamps to the 64-entry floor: the smallest legal
            // journal, maximizing compaction pressure
            rm.set_index_journal_limit(Some(1));
        }
        if rng.range_u64(0, 1) == 1 {
            rm.set_feasible_bitmap(false); // start half the cases on the flat path
        }

        let mut shapes: Vec<(Vec<u64>, ShapeId)> = Vec::new();
        fn intern(
            rm: &mut ResourceManager,
            shapes: &mut Vec<(Vec<u64>, ShapeId)>,
            rng: &mut Pcg64,
        ) {
            let vec = vec![rng.range_u64(0, 2), rng.range_u64(0, 16)];
            let sid = rm.intern_shape(&vec);
            if !shapes.iter().any(|(v, _)| *v == vec) {
                shapes.push((vec, sid));
            }
        }
        for _ in 0..rng.range_u64(1, 4) {
            intern(&mut rm, &mut shapes, rng);
        }

        let mut live: Vec<Job> = Vec::new();
        let mut next_id = 1u64;
        // 150 ops × a few slices per allocate ≫ the 64-entry journal floor:
        // compaction paths are exercised on small systems every case
        for _ in 0..150 {
            match rng.range_u64(0, 10) {
                0..=3 => {
                    // allocate a random job of a random interned shape
                    let (vec, sid) = &shapes[rng.range_u64(0, shapes.len() as u64 - 1) as usize];
                    let slots = rng.range_u64(1, 8) as u32;
                    if let Some(alloc) = oracle_place(&rm, vec, slots) {
                        let mut j = probe(vec, *sid, slots);
                        j.id = next_id;
                        next_id += 1;
                        rm.allocate(&j, alloc).expect("oracle placement is valid");
                        live.push(j);
                    }
                }
                4..=6 => {
                    if !live.is_empty() {
                        let i = rng.range_u64(0, live.len() as u64 - 1) as usize;
                        let j = live.swap_remove(i);
                        rm.release(&j).expect("live job releases");
                    }
                }
                7 => {
                    rm.set_node_down(rng.range_u64(0, nodes - 1) as usize);
                }
                8 => {
                    rm.set_node_up(rng.range_u64(0, nodes - 1) as usize);
                }
                9 => {
                    // flip the bitmap layer mid-sequence: toggling marks
                    // every shape stale, so the next query rebuilds (or
                    // drops) both layers from scratch
                    let on = rm.feasible_bitmap_enabled();
                    rm.set_feasible_bitmap(!on);
                }
                _ => {
                    // intern a fresh shape mid-sequence: it must observe the
                    // *current* state on its first query
                    intern(&mut rm, &mut shapes, rng);
                }
            }
            assert_index_matches_oracle(&rm, &shapes);
            rm.assert_index_bitmap_invariants();
        }
    });
}

fn run_with_index(
    jobs: Vec<Job>,
    sys: SysConfig,
    label: &str,
    use_shape_index: bool,
) -> SimOutput {
    let opts = SimOptions {
        output: OutputCollector::in_memory(true, true),
        mem_sample_secs: 0,
        use_shape_index,
        ..Default::default()
    };
    let mut sim =
        Simulator::from_jobs(jobs, sys, dispatcher_from_label(label).unwrap(), opts);
    sim.run().expect("simulation completes")
}

/// Render the deterministic portion of a run: the full jobs.csv bytes plus
/// the timing-free perf columns (dispatch/other ns and RSS are wall-clock
/// noise and excluded by design — same rule as the campaign store's
/// byte-identical index.json).
fn deterministic_bytes(out: &SimOutput) -> String {
    let mut s = String::from("jobs.csv\n");
    for j in &out.jobs {
        s.push_str(&j.to_csv());
        s.push('\n');
    }
    s.push_str("perf(t,queue,running,started)\n");
    for p in &out.perf {
        s.push_str(&format!("{},{},{},{}\n", p.t, p.queue_len, p.running, p.started));
    }
    s.push_str(&format!(
        "completed={} rejected={} makespan={} slowdown_sum={} wait_sum={} max_queue={}\n",
        out.jobs_completed,
        out.jobs_rejected,
        out.makespan,
        out.slowdown_sum,
        out.wait_sum,
        out.max_queue
    ));
    s
}

/// Byte identity across the index toggle, for every shipped scheduler ×
/// allocator family (including the backfillers, whose shadow/profile math
/// must keep seeing the exact same committed state).
#[test]
fn simulations_are_byte_identical_with_index_disabled() {
    let mut rng = Pcg64::new(0xB17E);
    let jobs = arb_jobs(&mut rng, 120, 12, 3);
    let sys = SysConfig::homogeneous("ab", 6, &[("core", 8), ("gpu", 1), ("mem", 64)], 0);
    for label in
        ["FIFO-FF", "SJF-BF", "LJF-WF", "EBF-FF", "EBF_SJF-BF", "CBF-FF", "FIFO_RND-FF"]
    {
        let on = run_with_index(jobs.clone(), sys.clone(), label, true);
        let off = run_with_index(jobs.clone(), sys.clone(), label, false);
        assert_eq!(
            deterministic_bytes(&on),
            deterministic_bytes(&off),
            "{label}: the availability index changed simulation results"
        );
        assert!(on.jobs_completed > 0, "{label}: degenerate case");
    }
}

/// Same guarantee under capacity perturbations: failure windows drive
/// set_node_down/up through the index's journal mid-simulation.
#[test]
fn failure_scenarios_are_byte_identical_with_index_disabled() {
    use accasim::addons::FailureInjector;
    let mut rng = Pcg64::new(0xFA11);
    let jobs = arb_jobs(&mut rng, 80, 8, 2);
    let sys = SysConfig::homogeneous("abf", 4, &[("core", 8), ("mem", 64)], 0);
    let run = |use_shape_index: bool| {
        let opts = SimOptions {
            output: OutputCollector::in_memory(true, true),
            addons: vec![Box::new(FailureInjector::new(vec![
                (0, 100, 5_000),
                (1, 2_000, 20_000),
                (2, 100, 3_000),
            ]))],
            mem_sample_secs: 0,
            use_shape_index,
            ..Default::default()
        };
        let mut sim = Simulator::from_jobs(
            jobs.clone(),
            sys.clone(),
            dispatcher_from_label("FIFO-FF").unwrap(),
            opts,
        );
        sim.run().expect("simulation completes")
    };
    let (on, off) = (run(true), run(false));
    assert_eq!(deterministic_bytes(&on), deterministic_bytes(&off));
    assert_eq!(on.addon_wakes, off.addon_wakes);
}

/// Campaign-level byte identity: the same matrix executed with the index on
/// and off must leave byte-identical stores — summary.csv, index.json, the
/// fig10/fig11 plot CSVs and every per-run jobs.csv (perf.csv agrees on its
/// deterministic columns; its ns/RSS fields are wall-clock noise).
#[test]
fn campaign_store_is_byte_identical_with_index_disabled() {
    use accasim::campaign::{Campaign, CampaignSpec};
    let tmp = tempfile::tempdir().unwrap();
    let spec = || {
        let mut s = CampaignSpec::new("abidx");
        s.add_trace("seth", 0.0005).add_system_trace("seth");
        s.add_dispatcher("FIFO-FF").add_dispatcher("SJF-BF");
        s.seeds = vec![1, 2];
        s
    };
    let dir_on = tmp.path().join("on");
    let dir_off = tmp.path().join("off");
    let rep_on = Campaign::new(spec(), &dir_on).shape_index(true).run().unwrap();
    let rep_off = Campaign::new(spec(), &dir_off).shape_index(false).run().unwrap();
    assert_eq!(rep_on.records.len(), 4);
    assert_eq!(rep_on.records.len(), rep_off.records.len());

    let read = |p: &std::path::Path| std::fs::read_to_string(p).unwrap();
    for file in ["summary.csv", "index.json", "plots/fig10_slowdown.csv", "plots/fig11_queue.csv"]
    {
        assert_eq!(
            read(&dir_on.join(file)),
            read(&dir_off.join(file)),
            "{file} must not depend on the availability index"
        );
    }
    for rec in &rep_on.records {
        let run = |d: &std::path::Path| d.join("runs").join(&rec.run_id);
        assert_eq!(
            read(&run(&dir_on).join("jobs.csv")),
            read(&run(&dir_off).join("jobs.csv")),
            "{}: jobs.csv must not depend on the availability index",
            rec.run_id
        );
        let strip = |text: String| {
            // keep the deterministic perf columns: t,queue_len,running,started
            text.lines()
                .skip(1)
                .map(|l| {
                    let f: Vec<&str> = l.split(',').collect();
                    format!("{},{},{},{}", f[0], f[3], f[4], f[5])
                })
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(
            strip(read(&run(&dir_on).join("perf.csv"))),
            strip(read(&run(&dir_off).join("perf.csv"))),
            "{}: perf.csv deterministic columns diverged",
            rec.run_id
        );
    }
}

/// Byte identity across the feasibility-bitmap toggle at scale: every
/// dispatcher × allocator family on a ≥2k-node system under a failure
/// storm (dozens of staggered down/up windows driving zero-crossing bit
/// flips and journal churn through the bitmap maintenance path). The
/// flat-scan enumeration and the enumerate-then-fill placement stay
/// compiled in as the in-tree oracle (`use_feasible_bitmap = false`);
/// the hierarchical enumeration and the First-Fit early-exit streaming
/// placement must be indistinguishable from them in every output byte.
#[test]
fn simulations_are_byte_identical_with_bitmap_disabled() {
    use accasim::addons::FailureInjector;
    let mut rng = Pcg64::new(0xB17A);
    let jobs = arb_jobs(&mut rng, 150, 24, 3);
    let sys = SysConfig::homogeneous("abxl", 2048, &[("core", 8), ("gpu", 1), ("mem", 64)], 0);
    // failure storm: 48 staggered windows spread across the machine
    let storm: Vec<(u32, u64, u64)> = (0..48u64)
        .map(|i| (((i * 331) % 2048) as u32, 50 + i * 37, 50 + i * 37 + 2_500))
        .collect();
    let run = |label: &str, use_feasible_bitmap: bool| {
        let opts = SimOptions {
            output: OutputCollector::in_memory(true, true),
            addons: vec![Box::new(FailureInjector::new(storm.clone()))],
            mem_sample_secs: 0,
            use_feasible_bitmap,
            ..Default::default()
        };
        let mut sim = Simulator::from_jobs(
            jobs.clone(),
            sys.clone(),
            dispatcher_from_label(label).unwrap(),
            opts,
        );
        sim.run().expect("simulation completes")
    };
    for label in ["FIFO-FF", "SJF-BF", "LJF-WF", "EBF-FF", "CBF-FF"] {
        let on = run(label, true);
        let off = run(label, false);
        assert_eq!(
            deterministic_bytes(&on),
            deterministic_bytes(&off),
            "{label}: the feasibility bitmaps changed simulation results"
        );
        assert_eq!(on.addon_wakes, off.addon_wakes, "{label}");
        assert!(on.jobs_completed > 0, "{label}: degenerate case");
    }
}

/// Campaign-level byte identity across the feasibility-bitmap toggle:
/// like the shape-index campaign A/B above, the same matrix run with
/// bitmaps on and off must leave byte-identical stores.
#[test]
fn campaign_store_is_byte_identical_with_bitmap_disabled() {
    use accasim::campaign::{Campaign, CampaignSpec};
    let tmp = tempfile::tempdir().unwrap();
    let spec = || {
        let mut s = CampaignSpec::new("abbmp");
        s.add_trace("seth", 0.0005).add_system_trace("seth");
        s.add_dispatcher("FIFO-FF").add_dispatcher("SJF-BF");
        s.seeds = vec![1, 2];
        s
    };
    let dir_on = tmp.path().join("on");
    let dir_off = tmp.path().join("off");
    let rep_on = Campaign::new(spec(), &dir_on).feasible_bitmap(true).run().unwrap();
    let rep_off = Campaign::new(spec(), &dir_off).feasible_bitmap(false).run().unwrap();
    assert_eq!(rep_on.records.len(), 4);
    assert_eq!(rep_on.records.len(), rep_off.records.len());
    let read = |p: &std::path::Path| std::fs::read_to_string(p).unwrap();
    for file in ["summary.csv", "index.json", "plots/fig10_slowdown.csv", "plots/fig11_queue.csv"]
    {
        assert_eq!(
            read(&dir_on.join(file)),
            read(&dir_off.join(file)),
            "{file} must not depend on the feasibility bitmaps"
        );
    }
    for rec in &rep_on.records {
        let run = |d: &std::path::Path| d.join("runs").join(&rec.run_id);
        assert_eq!(
            read(&run(&dir_on).join("jobs.csv")),
            read(&run(&dir_off).join("jobs.csv")),
            "{}: jobs.csv must not depend on the feasibility bitmaps",
            rec.run_id
        );
    }
}

/// The simulator interns shapes at submission: after a run the manager's
/// table holds exactly the distinct per_slot vectors of the workload.
#[test]
fn simulator_interns_shapes_at_submission() {
    let mk = |id: u64, mem: u64| Job {
        id,
        submit: 0,
        duration: 5,
        req_time: 5,
        slots: 1,
        per_slot: vec![1, mem],
        user: 0,
        app: 0,
        status: 1,
        shape: ShapeId::UNSET,
    };
    let jobs = vec![mk(1, 10), mk(2, 10), mk(3, 20), mk(4, 10), mk(5, 30)];
    let sys = SysConfig::homogeneous("intern", 2, &[("core", 4), ("mem", 100)], 0);
    let mut sim = Simulator::from_jobs(
        jobs,
        sys,
        dispatcher_from_label("FIFO-FF").unwrap(),
        SimOptions { mem_sample_secs: 0, ..Default::default() },
    );
    let out = sim.run().unwrap();
    assert_eq!(out.jobs_completed, 5);
    assert_eq!(sim.resource_manager().shape_count(), 3, "three distinct shapes");
}
