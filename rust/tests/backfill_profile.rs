//! Backfilling-profile invariants (DESIGN.md §Perf, §Backfilling profiles).
//!
//! Two families of guarantees, mirroring `availability_index.rs`:
//!
//! 1. **Oracle equivalence** — after every allocate/release/cycle-advance/
//!    intern step of a randomized sequence, the incremental profile's
//!    head-reservation probe must equal a naive shadow replay (the EASY
//!    oracle) and its piecewise snapshot must equal a naive per-job
//!    rebuild (the CBF oracle), at every breakpoint — including after
//!    journal compaction and mid-sequence shape interning — with zero
//!    demotions while registration covers the running set.
//! 2. **Byte identity** — simulations and whole campaigns executed with
//!    the profile disabled (`SimOptions::use_backfill_profile = false`,
//!    the naive rebuild path) must produce byte-identical outputs for
//!    every backfilling dispatcher, under estimate noise, failure storms
//!    and power caps alike: speed must not change results.

use accasim::config::SysConfig;
use accasim::dispatch::dispatcher_from_label;
use accasim::output::OutputCollector;
use accasim::resources::{
    hostable_slots_in, Allocation, ProfileProbe, ResourceManager, ShapeId,
};
use accasim::rng::Pcg64;
use accasim::sim::{SimOptions, SimOutput, Simulator};
use accasim::testkit::{arb_jobs, check};
use accasim::testutil as tempfile;
use accasim::workload::Job;

fn probe(per_slot: &[u64], slots: u32) -> Job {
    Job {
        id: 0,
        submit: 0,
        duration: 10,
        req_time: 10,
        slots,
        per_slot: per_slot.to_vec(),
        user: 0,
        app: 0,
        status: 1,
        shape: ShapeId::UNSET,
    }
}

/// A job the test committed through the manager, with everything the
/// naive oracles need to replay its future release.
struct Tracked {
    job: Job,
    alloc: Allocation,
    start: u64,
}

/// Greedy first-fit placement against the live free matrix, straight from
/// the public accessors (independent of the allocators under test).
fn greedy_place(rm: &ResourceManager, job: &Job) -> Option<Allocation> {
    let mut remaining = job.slots as u64;
    let mut slices = Vec::new();
    for n in 0..rm.num_nodes() {
        if remaining == 0 {
            break;
        }
        let h = hostable_slots_in(rm.node_free(n), &job.per_slot).min(remaining);
        if h > 0 {
            slices.push((n as u32, h as u32));
            remaining -= h;
        }
    }
    (remaining == 0).then_some(Allocation { slices })
}

/// The naive EASY oracle: shadow-replay the registered releases in
/// estimated-end order (dispatcher-clock clamped to `now + 1`) and return
/// the first group boundary after which the head fits, plus the shadow
/// free matrix with the head's greedy reservation deducted — exactly
/// `EasyBackfilling`'s pre-profile `reserve_head`.
fn naive_reserve(
    rm: &ResourceManager,
    head: &Job,
    now: u64,
    running: &[Tracked],
) -> Option<(u64, Vec<u64>)> {
    let mut sh = rm.shadow();
    let mut events: Vec<(u64, usize)> = running
        .iter()
        .enumerate()
        .map(|(i, t)| (t.job.estimated_completion_at(t.start).max(now + 1), i))
        .collect();
    events.sort_unstable();
    let mut idx = 0;
    while idx < events.len() {
        let t = events[idx].0;
        while idx < events.len() && events[idx].0 == t {
            let tr = &running[events[idx].1];
            sh.release(&tr.job, &tr.alloc);
            idx += 1;
        }
        if sh.can_host(head) {
            sh.reserve_greedy(head).expect("can_host implies the greedy fill");
            return Some((t, sh.free_matrix().to_vec()));
        }
    }
    None
}

/// The naive CBF oracle: the piecewise availability profile rebuilt per
/// running job — a base row at `now`, then one merged row per distinct
/// clamped estimated end — exactly `Profile::new`'s pre-profile path.
fn naive_profile(
    rm: &ResourceManager,
    now: u64,
    running: &[Tracked],
) -> (Vec<u64>, Vec<Vec<u64>>) {
    let types = rm.num_types();
    let mut events: Vec<(u64, usize)> = running
        .iter()
        .enumerate()
        .map(|(i, t)| (t.job.estimated_completion_at(t.start).max(now + 1), i))
        .collect();
    events.sort_unstable();
    let mut times = vec![now];
    let mut frees = vec![rm.free_matrix().to_vec()];
    for (t, i) in events {
        let tr = &running[i];
        let mut next = frees.last().unwrap().clone();
        for &(node, slots) in &tr.alloc.slices {
            let base = node as usize * types;
            for (rt, q) in tr.job.per_slot.iter().enumerate() {
                next[base + rt] += q * slots as u64;
            }
        }
        if *times.last().unwrap() == t {
            *frees.last_mut().unwrap() = next;
        } else {
            times.push(t);
            frees.push(next);
        }
    }
    (times, frees)
}

/// Assert both indexed probes equal their naive oracles for every shape,
/// across a spread of head sizes (fits-now, fits-later, never-fits).
fn assert_profile_matches_oracles(
    rm: &ResourceManager,
    now: u64,
    running: &[Tracked],
    shapes: &[Vec<u64>],
    rng: &mut Pcg64,
) {
    let mut out = Vec::new();
    for vec in shapes {
        for _ in 0..2 {
            let head = probe(vec, rng.range_u64(1, 12) as u32);
            let got = rm.profile_reserve_head(&head, now, running.len(), &mut out);
            match (got, naive_reserve(rm, &head, now, running)) {
                (ProfileProbe::Reserved(t), Some((et, efree))) => {
                    assert_eq!(t, et, "shape {vec:?} ×{}: reservation time", head.slots);
                    assert_eq!(
                        out, efree,
                        "shape {vec:?} ×{}: free-after matrix diverged",
                        head.slots
                    );
                }
                (ProfileProbe::NeverFits, None) => {}
                (got, expect) => panic!(
                    "shape {vec:?} ×{}: probe {got:?} vs oracle {:?}",
                    head.slots,
                    expect.map(|(t, _)| t)
                ),
            }
        }
    }
    let (mut times, mut frees) = (Vec::new(), Vec::new());
    assert!(
        rm.profile_snapshot(now, running.len(), &mut times, &mut frees),
        "snapshot must not demote while coverage holds"
    );
    let (etimes, efrees) = naive_profile(rm, now, running);
    assert_eq!(times, etimes, "snapshot breakpoints diverged");
    assert_eq!(frees, efrees, "snapshot free rows diverged");
}

/// The tentpole property: drive randomized allocate/release/cycle-advance
/// sequences through the manager (long enough on small systems to force
/// journal compactions) following the dispatch-cycle protocol — jobs
/// started this cycle stay pending until the next `begin_dispatch_cycle`
/// registers them, exactly as the simulator's event loop does — and
/// assert both profile probes equal the naive oracles after every step.
#[test]
fn prop_profile_matches_naive_oracles() {
    check("backfill-profile", 0xBF111, 25, |rng| {
        let nodes = rng.range_u64(1, 6);
        let sys = SysConfig::homogeneous(
            "bfp",
            nodes,
            &[("core", rng.range_u64(2, 8)), ("mem", rng.range_u64(8, 64))],
            0,
        );
        let mut rm = ResourceManager::from_config(&sys);
        let mut shapes: Vec<Vec<u64>> = vec![vec![1, rng.range_u64(1, 8)]];
        let mut now = 0u64;
        rm.begin_dispatch_cycle(now);
        // started in an earlier cycle → in the profile's registered set
        let mut registered: Vec<Tracked> = Vec::new();
        // started this cycle → committed resources but pending registration
        let mut pending: Vec<Tracked> = Vec::new();
        let mut next_id = 1u64;
        for _ in 0..200 {
            match rng.range_u64(0, 9) {
                0..=3 => {
                    // start a job of a random known shape
                    let i = rng.range_u64(0, shapes.len() as u64 - 1) as usize;
                    let mut j = probe(&shapes[i], rng.range_u64(1, 8) as u32);
                    j.id = next_id;
                    j.req_time = rng.range_u64(1, 2_000);
                    if let Some(alloc) = greedy_place(&rm, &j) {
                        next_id += 1;
                        rm.allocate(&j, alloc.clone()).expect("greedy placement is valid");
                        pending.push(Tracked { job: j, alloc, start: now });
                    }
                }
                4..=5 => {
                    // release a random live job (registered or pending)
                    let total = registered.len() + pending.len();
                    if total > 0 {
                        let i = rng.range_u64(0, total as u64 - 1) as usize;
                        let tr = if i < registered.len() {
                            registered.swap_remove(i)
                        } else {
                            pending.swap_remove(i - registered.len())
                        };
                        rm.release(&tr.job).expect("live job releases");
                    }
                }
                6..=8 => {
                    // next dispatch cycle: pending starts become registered
                    now += rng.range_u64(1, 1_500);
                    rm.begin_dispatch_cycle(now);
                    registered.append(&mut pending);
                }
                _ => {
                    // intern a fresh shape mid-sequence: its first probe
                    // must observe the current profile state
                    let vec = vec![1, rng.range_u64(0, 16)];
                    rm.intern_shape(&vec);
                    if !shapes.contains(&vec) {
                        shapes.push(vec);
                    }
                }
            }
            assert_profile_matches_oracles(&rm, now, &registered, &shapes, rng);
        }
        assert_eq!(rm.profile_demotions(), 0, "coverage was maintained throughout");
    });
}

fn run_with_profile(
    jobs: Vec<Job>,
    sys: SysConfig,
    label: &str,
    use_backfill_profile: bool,
) -> SimOutput {
    let opts = SimOptions {
        output: OutputCollector::in_memory(true, true),
        mem_sample_secs: 0,
        use_backfill_profile,
        ..Default::default()
    };
    let mut sim =
        Simulator::from_jobs(jobs, sys, dispatcher_from_label(label).unwrap(), opts);
    sim.run().expect("simulation completes")
}

/// Render the deterministic portion of a run: the full jobs.csv bytes plus
/// the timing-free perf columns (dispatch/other ns and RSS are wall-clock
/// noise and excluded by design — same rule as the campaign store's
/// byte-identical index.json).
fn deterministic_bytes(out: &SimOutput) -> String {
    let mut s = String::from("jobs.csv\n");
    for j in &out.jobs {
        s.push_str(&j.to_csv());
        s.push('\n');
    }
    s.push_str("perf(t,queue,running,started)\n");
    for p in &out.perf {
        s.push_str(&format!("{},{},{},{}\n", p.t, p.queue_len, p.running, p.started));
    }
    s.push_str(&format!(
        "completed={} rejected={} makespan={} slowdown_sum={} wait_sum={} max_queue={}\n",
        out.jobs_completed,
        out.jobs_rejected,
        out.makespan,
        out.slowdown_sum,
        out.wait_sum,
        out.max_queue
    ));
    s
}

/// Byte identity across the profile toggle for every shipped backfilling
/// dispatcher. The `arb_jobs` workload builds in runtime-estimate noise
/// (`req_time` is a 0.5–4× multiple of the true duration), so clamped,
/// exceeded and early-finishing estimates are all exercised.
#[test]
fn simulations_are_byte_identical_with_profile_disabled() {
    let mut rng = Pcg64::new(0xBF2);
    let jobs = arb_jobs(&mut rng, 120, 12, 3);
    let sys = SysConfig::homogeneous("abp", 6, &[("core", 8), ("gpu", 1), ("mem", 64)], 0);
    for label in ["EBF-FF", "EBF_SJF-BF", "EBF_LJF-FF", "CBF-FF"] {
        let on = run_with_profile(jobs.clone(), sys.clone(), label, true);
        let off = run_with_profile(jobs.clone(), sys.clone(), label, false);
        assert_eq!(
            deterministic_bytes(&on),
            deterministic_bytes(&off),
            "{label}: the backfilling profile changed simulation results"
        );
        assert!(on.jobs_completed > 0, "{label}: degenerate case");
    }
}

/// Same guarantee under a failure storm: down/up windows change capacity
/// mid-simulation while running jobs keep (and release) their slices, the
/// regime in which the naive rebuild and the incremental rows must agree
/// on every clamped estimate.
#[test]
fn failure_scenarios_are_byte_identical_with_profile_disabled() {
    use accasim::addons::FailureInjector;
    let mut rng = Pcg64::new(0xBF3);
    let jobs = arb_jobs(&mut rng, 80, 8, 2);
    let sys = SysConfig::homogeneous("abpf", 4, &[("core", 8), ("mem", 64)], 0);
    for label in ["EBF-FF", "CBF-FF"] {
        let run = |use_backfill_profile: bool| {
            let opts = SimOptions {
                output: OutputCollector::in_memory(true, true),
                addons: vec![Box::new(FailureInjector::new(vec![
                    (0, 100, 5_000),
                    (1, 2_000, 20_000),
                    (2, 100, 3_000),
                ]))],
                mem_sample_secs: 0,
                use_backfill_profile,
                ..Default::default()
            };
            let mut sim = Simulator::from_jobs(
                jobs.clone(),
                sys.clone(),
                dispatcher_from_label(label).unwrap(),
                opts,
            );
            sim.run().expect("simulation completes")
        };
        let (on, off) = (run(true), run(false));
        assert_eq!(
            deterministic_bytes(&on),
            deterministic_bytes(&off),
            "{label}: profile diverged under failure windows"
        );
        assert_eq!(on.addon_wakes, off.addon_wakes);
    }
}

/// Same guarantee under a power cap: `PowerCapped` un-commits same-cycle
/// starts (`rm.release` of a job allocated moments earlier), the one path
/// that releases a *pending* profile entry before it ever registers.
#[test]
fn power_cap_scenarios_are_byte_identical_with_profile_disabled() {
    use accasim::addons::PowerModel;
    use accasim::dispatch::{Dispatcher, EasyBackfilling, FirstFit, PowerCapped};
    let mut rng = Pcg64::new(0xBF4);
    let jobs = arb_jobs(&mut rng, 80, 8, 2);
    let sys = SysConfig::homogeneous("abpp", 4, &[("core", 8), ("mem", 64)], 0);
    let run = |use_backfill_profile: bool| {
        let capped = Dispatcher::new(
            Box::new(PowerCapped::new(Box::new(EasyBackfilling::new()), 900.0, 50.0)),
            Box::new(FirstFit::new()),
        );
        let opts = SimOptions {
            output: OutputCollector::in_memory(true, true),
            addons: vec![Box::new(PowerModel::new(100.0, 300.0))],
            mem_sample_secs: 0,
            use_backfill_profile,
            ..Default::default()
        };
        let mut sim = Simulator::from_jobs(jobs.clone(), sys.clone(), capped, opts);
        sim.run().expect("simulation completes")
    };
    let (on, off) = (run(true), run(false));
    assert_eq!(
        deterministic_bytes(&on),
        deterministic_bytes(&off),
        "PCAP[EBF]-FF: profile diverged across power-cap deferrals"
    );
    assert!(on.jobs_completed > 0);
}

/// Campaign-level byte identity: the same backfilling matrix executed with
/// the profile on and off must leave byte-identical stores — summary.csv,
/// index.json and every per-run jobs.csv (perf.csv agrees on its
/// deterministic columns; its ns/RSS fields are wall-clock noise).
#[test]
fn campaign_store_is_byte_identical_with_profile_disabled() {
    use accasim::campaign::{Campaign, CampaignSpec};
    let tmp = tempfile::tempdir().unwrap();
    let spec = || {
        let mut s = CampaignSpec::new("abprofile");
        s.add_trace("seth", 0.0005).add_system_trace("seth");
        s.add_dispatcher("EBF-FF").add_dispatcher("CBF-FF");
        s.seeds = vec![1, 2];
        s
    };
    let dir_on = tmp.path().join("on");
    let dir_off = tmp.path().join("off");
    let rep_on = Campaign::new(spec(), &dir_on).backfill_profile(true).run().unwrap();
    let rep_off = Campaign::new(spec(), &dir_off).backfill_profile(false).run().unwrap();
    assert_eq!(rep_on.records.len(), 4);
    assert_eq!(rep_on.records.len(), rep_off.records.len());

    let read = |p: &std::path::Path| std::fs::read_to_string(p).unwrap();
    for file in ["summary.csv", "index.json"] {
        assert_eq!(
            read(&dir_on.join(file)),
            read(&dir_off.join(file)),
            "{file} must not depend on the backfilling profile"
        );
    }
    for rec in &rep_on.records {
        let run = |d: &std::path::Path| d.join("runs").join(&rec.run_id);
        assert_eq!(
            read(&run(&dir_on).join("jobs.csv")),
            read(&run(&dir_off).join("jobs.csv")),
            "{}: jobs.csv must not depend on the backfilling profile",
            rec.run_id
        );
        let strip = |text: String| {
            // keep the deterministic perf columns: t,queue_len,running,started
            text.lines()
                .skip(1)
                .map(|l| {
                    let f: Vec<&str> = l.split(',').collect();
                    format!("{},{},{},{}", f[0], f[3], f[4], f[5])
                })
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(
            strip(read(&run(&dir_on).join("perf.csv"))),
            strip(read(&run(&dir_off).join("perf.csv"))),
            "{}: perf.csv deterministic columns diverged",
            rec.run_id
        );
    }
}
