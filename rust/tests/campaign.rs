//! Campaign-engine determinism and resume contracts (ISSUE 2 acceptance):
//! a campaign of ≥ 2 workloads × 2 dispatchers × 2 seeds run with 4 worker
//! threads yields byte-identical `index.json` and plot CSVs to the serial
//! run, and re-invoking a finished campaign skips every run.

use accasim::campaign::{run_dir, Campaign, CampaignSpec, PowerSpec, ScenarioSpec};
use accasim::testutil as tempfile;
use std::path::Path;

/// ≥ 2 workloads (a trace synthesizer + a fixed SWF) × 1 system ×
/// 2 dispatchers × 2 scenarios × 2 seeds = 16 runs.
fn acceptance_spec(swf: &Path) -> CampaignSpec {
    let mut spec = CampaignSpec::new("acceptance");
    spec.add_trace("seth", 0.0005)
        .add_swf(swf)
        .add_system_trace("seth")
        .add_dispatcher("FIFO-FF")
        .add_dispatcher("SJF-FF")
        .add_scenario(ScenarioSpec {
            power: Some(PowerSpec { idle_w: 80.0, max_w: 350.0, cadence: 3600 }),
            // node 0 down for ~3h early in the (scaled) Seth span, so the
            // scenario actually perturbs scheduling in those runs
            failures: vec![(0, 1_025_830_000, 1_025_840_000)],
            ..ScenarioSpec::named("power")
        });
    spec.seeds = vec![1, 2];
    spec
}

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

#[test]
fn parallel_run_is_byte_identical_to_serial_and_resumes() {
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("fixed.swf");
    accasim::traces::RICC.synthesize(&swf, 0.0002, 7).unwrap(); // ~90 jobs

    let serial_out = tmp.path().join("serial");
    let parallel_out = tmp.path().join("parallel");
    let serial =
        Campaign::new(acceptance_spec(&swf), &serial_out).jobs(1).run().unwrap();
    let parallel =
        Campaign::new(acceptance_spec(&swf), &parallel_out).jobs(4).run().unwrap();
    assert_eq!(serial.records.len(), 16);
    assert_eq!(serial.executed, 16);
    assert_eq!(parallel.executed, 16);

    // campaign-level artifacts: byte-identical
    assert_eq!(
        read(&serial.index),
        read(&parallel.index),
        "index.json must not depend on worker count"
    );
    for file in ["plots/fig10_slowdown.csv", "plots/fig11_queue.csv", "summary.csv"] {
        assert_eq!(
            read(&serial_out.join(file)),
            read(&parallel_out.join(file)),
            "{file} must not depend on worker count"
        );
    }
    // per-run decision records: byte-identical too
    for rec in &serial.records {
        assert_eq!(
            read(&run_dir(&serial_out, &rec.run_id).join("jobs.csv")),
            read(&run_dir(&parallel_out, &rec.run_id).join("jobs.csv")),
            "{}: jobs.csv must not depend on worker count",
            rec.run_id
        );
    }

    // re-invoking the finished campaign skips every run and leaves the
    // artifacts unchanged
    let before = read(&parallel.index);
    let again =
        Campaign::new(acceptance_spec(&swf), &parallel_out).jobs(4).run().unwrap();
    assert_eq!(again.executed, 0);
    assert_eq!(again.skipped, 16);
    assert_eq!(read(&again.index), before);
}

#[test]
fn partial_store_resumes_only_missing_runs() {
    let tmp = tempfile::tempdir().unwrap();
    let mut spec = CampaignSpec::new("partial");
    spec.add_trace("seth", 0.0005)
        .add_system_trace("seth")
        .add_dispatcher("FIFO-FF")
        .add_dispatcher("SJF-FF");
    spec.seeds = vec![1, 2];
    let out = tmp.path().join("out");
    let first = Campaign::new(spec.clone(), &out).jobs(2).run().unwrap();
    assert_eq!(first.executed, 4);
    let index_before = read(&first.index);

    // deleting one manifest (simulating a crash mid-run) re-runs only it
    let victim = &first.records[2];
    std::fs::remove_file(run_dir(&out, &victim.run_id).join("run.json")).unwrap();
    let resumed = Campaign::new(spec, &out).jobs(2).run().unwrap();
    assert_eq!(resumed.executed, 1);
    assert_eq!(resumed.skipped, 3);
    assert_eq!(read(&resumed.index), index_before, "re-run reproduces the same results");
}

#[test]
fn scenarios_shape_results() {
    // A failure window covering the workload's early hours must change
    // scheduling relative to baseline, and the power scenario must publish
    // energy into the manifests.
    let tmp = tempfile::tempdir().unwrap();
    let mut spec = CampaignSpec::new("scenarios");
    spec.add_trace("seth", 0.0005).add_system_trace("seth").add_dispatcher("FIFO-FF");
    spec.add_scenario(ScenarioSpec {
        power: Some(PowerSpec { idle_w: 80.0, max_w: 350.0, cadence: 3600 }),
        ..ScenarioSpec::named("power")
    });
    spec.seeds = vec![1];
    let report = Campaign::new(spec, tmp.path().join("out")).run().unwrap();
    assert_eq!(report.records.len(), 2);
    let baseline = &report.records[0];
    let power = &report.records[1];
    assert_eq!(baseline.scenario, "baseline");
    assert!(!baseline.extra.contains_key("power.energy_kj"));
    assert!(power.extra.get("power.energy_kj").copied().unwrap_or(0.0) > 0.0);
    // the addon is observation-only: decisions stay identical
    assert_eq!(baseline.slowdown_sum, power.slowdown_sum);
}
