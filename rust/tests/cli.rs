//! End-to-end tests of the `accasim` binary: every subcommand run against
//! real (synthesized) inputs, checking exit codes and output contracts.

use accasim::testutil as tempfile;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_accasim"))
}

/// Synthesize a small Seth slice + config into a temp dir.
fn fixtures() -> (tempfile::TempDir, std::path::PathBuf, std::path::PathBuf) {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("seth.swf");
    let cfg = dir.path().join("seth.json");
    accasim::traces::SETH.synthesize(&swf, 0.001, 1).unwrap();
    accasim::traces::SETH.sys_config().write_json_file(&cfg).unwrap();
    (dir, swf, cfg)
}

#[test]
fn no_args_prints_usage() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_command_fails() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn simulate_reports_summary_and_writes_csv() {
    let (dir, swf, cfg) = fixtures();
    let jobs_csv = dir.path().join("jobs.csv");
    let out = bin()
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--dispatcher",
            "SJF-BF",
            "--out-jobs",
            jobs_csv.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("dispatcher        : SJF-BF"));
    assert!(stdout.contains("jobs completed    : 203"));
    let records = accasim::output::read_job_csv(&jobs_csv).unwrap();
    assert_eq!(records.len(), 203);
}

#[test]
fn simulate_with_addons_reports_energy_and_failures() {
    let (_dir, swf, cfg) = fixtures();
    let out = bin()
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--power",
            "95,220",
            "--power-cadence",
            "3600",
            "--fail",
            "0:0:864000",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("power.energy_kj"), "missing energy line:\n{stdout}");
    assert!(stdout.contains("failures.down_nodes"));
    assert!(stdout.contains("addon wakes"));
}

#[test]
fn simulate_rejects_out_of_range_fail_node() {
    let (_dir, swf, cfg) = fixtures();
    let out = bin()
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--fail",
            "9999:0:10",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("9999"));
}

#[test]
fn simulate_rejects_malformed_fail_plan() {
    let (_dir, swf, cfg) = fixtures();
    let out = bin()
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--fail",
            "0:500", // missing repair_at
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--fail"));
}

#[test]
fn simulate_rejects_unknown_flag() {
    let (_dir, swf, cfg) = fixtures();
    let out = bin()
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--dispather", // typo
            "SJF-BF",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("dispather"));
}

#[test]
fn experiment_runs_cross_product() {
    let (dir, swf, cfg) = fixtures();
    let out = bin()
        .current_dir(dir.path())
        .args([
            "experiment",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--schedulers",
            "FIFO,SJF",
            "--allocators",
            "FF",
            "--name",
            "clitest",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("FIFO-FF"));
    assert!(stdout.contains("SJF-FF"));
    assert!(dir.path().join("results/clitest/fig10_slowdown.csv").exists());
}

#[test]
fn campaign_run_executes_resumes_and_reports_status() {
    let dir = tempfile::tempdir().unwrap();
    let spec = dir.path().join("study.json");
    std::fs::write(
        &spec,
        r#"{
            "name": "clicamp",
            "workloads": [{"trace": "seth", "scale": 0.0005}],
            "systems": [{"trace": "seth"}],
            "dispatchers": ["FIFO-FF", "SJF-FF"],
            "seeds": [1, 2]
        }"#,
    )
    .unwrap();
    let out_dir = dir.path().join("camp");
    let run = |args: &[&str]| {
        let out = bin().args(args).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let base = ["campaign", "run", spec.to_str().unwrap(), "--out", out_dir.to_str().unwrap()];
    let first = run(&[&base[..], &["--jobs", "2"][..]].concat());
    assert!(first.contains("4 run(s) executed, 0 skipped"), "{first}");
    assert!(first.contains("FIFO-FF") && first.contains("SJF-FF"), "{first}");
    assert!(out_dir.join("index.json").exists());
    assert!(out_dir.join("plots/fig10_slowdown.csv").exists());
    assert!(out_dir.join("summary.csv").exists());
    // resume: nothing left to execute
    let second = run(&base);
    assert!(second.contains("0 run(s) executed, 4 skipped"), "{second}");
    let status = run(&["campaign", "status", spec.to_str().unwrap(), "--out",
        out_dir.to_str().unwrap()]);
    assert!(status.contains("4/4"), "{status}");
}

#[test]
fn campaign_compare_writes_report_and_requires_a_store() {
    let dir = tempfile::tempdir().unwrap();
    let spec = dir.path().join("study.json");
    std::fs::write(
        &spec,
        r#"{
            "name": "clicmp",
            "workloads": [{"trace": "seth", "scale": 0.0005}],
            "systems": [{"trace": "seth"}],
            "dispatchers": ["FIFO-FF", "SJF-FF"],
            "seeds": [1, 2]
        }"#,
    )
    .unwrap();
    let out_dir = dir.path().join("camp");
    let spec_s = spec.to_str().unwrap();
    let out_s = out_dir.to_str().unwrap();

    // comparing before running points at `campaign run`
    let early = bin().args(["campaign", "compare", spec_s, "--out", out_s]).output().unwrap();
    assert!(!early.status.success());
    assert!(String::from_utf8_lossy(&early.stderr).contains("campaign run"));

    let run = bin().args(["campaign", "run", spec_s, "--out", out_s]).output().unwrap();
    assert!(run.status.success(), "{}", String::from_utf8_lossy(&run.stderr));
    let cmp = bin()
        .args(["campaign", "compare", spec_s, "--out", out_s, "--baseline", "FIFO-FF"])
        .output()
        .unwrap();
    assert!(cmp.status.success(), "{}", String::from_utf8_lossy(&cmp.stderr));
    let stdout = String::from_utf8_lossy(&cmp.stdout);
    assert!(stdout.contains("baseline FIFO-FF"), "{stdout}");
    assert!(stdout.contains("SJF-FF"), "{stdout}");
    for f in ["deltas.csv", "ranks.csv", "report.md", "delta_dist.csv"] {
        assert!(out_dir.join("comparisons").join(f).exists(), "{f}");
    }
    // an unknown metric is rejected with the valid choices
    let bad = bin()
        .args(["campaign", "compare", spec_s, "--out", out_s, "--metric", "frobness"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("slowdown"));
}

#[test]
fn campaign_compare_rejects_spec_drift() {
    let dir = tempfile::tempdir().unwrap();
    let spec = dir.path().join("study.json");
    let body = |seeds: &str| {
        format!(
            r#"{{"name": "drift",
                "workloads": [{{"trace": "seth", "scale": 0.0005}}],
                "systems": [{{"trace": "seth"}}],
                "dispatchers": ["FIFO-FF", "SJF-FF"],
                "seeds": {seeds}}}"#
        )
    };
    std::fs::write(&spec, body("[1]")).unwrap();
    let out_dir = dir.path().join("camp");
    let (spec_s, out_s) = (spec.to_str().unwrap().to_string(), out_dir.to_str().unwrap());
    let run = bin().args(["campaign", "run", &spec_s, "--out", out_s]).output().unwrap();
    assert!(run.status.success(), "{}", String::from_utf8_lossy(&run.stderr));
    // editing the spec (different seeds) invalidates the stored comparison
    std::fs::write(&spec, body("[1, 2]")).unwrap();
    let cmp = bin().args(["campaign", "compare", &spec_s, "--out", out_s]).output().unwrap();
    assert!(!cmp.status.success());
    assert!(
        String::from_utf8_lossy(&cmp.stderr).contains("re-run the campaign"),
        "{}",
        String::from_utf8_lossy(&cmp.stderr)
    );
}

#[test]
fn campaign_run_warns_about_skipped_workload_lines() {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("broken.swf");
    std::fs::write(
        &swf,
        "1 0 -1 60 1 -1 -1 1 120 -1 1 1 1 1 1 1 -1 -1\n\
         not a data line at all\n\
         2 5 -1 30 1 -1 -1 1 60 -1 1 1 1 1 1 1 -1 -1\n",
    )
    .unwrap();
    let cfg = dir.path().join("sys.json");
    accasim::config::SysConfig::homogeneous("tiny", 2, &[("core", 2)], 0)
        .write_json_file(&cfg)
        .unwrap();
    let spec = dir.path().join("study.json");
    std::fs::write(
        &spec,
        format!(
            r#"{{
                "name": "skipwarn",
                "workloads": [{{"swf": {:?}}}],
                "systems": [{{"name": "tiny", "path": {:?}}}],
                "dispatchers": ["FIFO-FF"]
            }}"#,
            swf.to_str().unwrap(),
            cfg.to_str().unwrap()
        ),
    )
    .unwrap();
    let out_dir = dir.path().join("camp");
    let out = bin()
        .args(["campaign", "run", spec.to_str().unwrap(), "--out", out_dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("1 malformed workload line(s) skipped across 1 run(s)"),
        "missing skip warning:\n{stderr}"
    );
    // …and the count is recorded in the run manifest
    let idx = accasim::campaign::load_index(&out_dir).unwrap();
    assert_eq!(idx.records[0].lines_skipped, 1);
}

#[test]
fn campaign_rejects_bad_spec() {
    let dir = tempfile::tempdir().unwrap();
    let spec = dir.path().join("bad.json");
    std::fs::write(&spec, r#"{"name": "x", "workloads": [], "systems": [],
        "dispatchers": []}"#).unwrap();
    let out = bin().args(["campaign", "run", spec.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("workloads"));
}

#[test]
fn generate_produces_valid_swf() {
    let (dir, swf, cfg) = fixtures();
    let gen = dir.path().join("gen.swf");
    let out = bin()
        .args([
            "generate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--jobs",
            "500",
            "--out",
            gen.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let n = accasim::workload::SwfReader::open(&gen).unwrap().count();
    assert_eq!(n, 500);
    // generated workload passes the linter
    let lint = bin().args(["validate", gen.to_str().unwrap()]).output().unwrap();
    assert!(lint.status.success(), "{}", String::from_utf8_lossy(&lint.stdout));
}

#[test]
fn validate_flags_broken_workload() {
    let dir = tempfile::tempdir().unwrap();
    let bad = dir.path().join("bad.swf");
    std::fs::write(&bad, "1 100 -1 -1 2 -1 -1 2 120 -1 1 1 1 1 1 1 -1 -1\n").unwrap();
    let out = bin().args(["validate", bad.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("run time"));
}

#[test]
fn status_renders_panels() {
    let (_dir, swf, cfg) = fixtures();
    let out = bin()
        .args(["status", swf.to_str().unwrap(), "--sys", cfg.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("simulation time"));
    assert!(stdout.contains("core"));
}

#[test]
fn traces_materializes_into_dir() {
    let dir = tempfile::tempdir().unwrap();
    let out = bin()
        .args([
            "traces",
            "ricc",
            "--scale",
            "0.0005",
            "--dir",
            dir.path().to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.path().join("ricc_s1.swf").exists());
    assert!(dir.path().join("ricc.json").exists());
}

#[test]
fn analyze_reads_saved_records() {
    let (dir, swf, cfg) = fixtures();
    let jobs_csv = dir.path().join("jobs.csv");
    bin()
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--out-jobs",
            jobs_csv.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let out = bin().args(["analyze", jobs_csv.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("203 jobs"));
    assert!(stdout.contains("wait by job size"));
    assert!(stdout.contains("peak busy slots"));
}

#[test]
fn run_one_emits_result_line() {
    let (_dir, swf, cfg) = fixtures();
    let out = bin()
        .args([
            "run-one",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--mode",
            "eager-heavy",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout.lines().find(|l| l.starts_with("RESULT,")).unwrap();
    assert_eq!(line.split(',').count(), 7);
}
