//! Campaign-comparator contracts (ISSUE 3 acceptance): pairing is by
//! repetition seed (not store order), missing repetitions degrade to
//! warnings, comparison artifacts are byte-identical across worker counts
//! and re-invocations, and a single-dispatcher store is a clear error.

use accasim::campaign::{
    load_index, run_dir, Campaign, CampaignSpec, CompareOptions, Comparison, Metric, PowerSpec,
    ScenarioSpec,
};
use accasim::testutil as tempfile;
use accasim::util::json::Json;
use std::path::Path;

/// 1 trace workload × 1 system × 2 dispatchers × 2 scenarios (baseline +
/// power) × 3 seeds = 12 runs.
fn spec() -> CampaignSpec {
    let mut spec = CampaignSpec::new("cmp");
    spec.add_trace("seth", 0.0005)
        .add_system_trace("seth")
        .add_dispatcher("FIFO-FF")
        .add_dispatcher("SJF-FF")
        .add_scenario(ScenarioSpec {
            power: Some(PowerSpec { idle_w: 80.0, max_w: 350.0, cadence: 3600 }),
            ..ScenarioSpec::named("power")
        });
    spec.seeds = vec![1, 2, 3];
    spec
}

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

const FILES: [&str; 4] =
    ["comparisons/deltas.csv", "comparisons/ranks.csv", "comparisons/report.md",
     "comparisons/delta_dist.csv"];

#[test]
fn comparison_is_byte_identical_across_worker_counts_and_reinvocation() {
    let tmp = tempfile::tempdir().unwrap();
    let serial_out = tmp.path().join("serial");
    let parallel_out = tmp.path().join("parallel");
    Campaign::new(spec(), &serial_out).jobs(1).run().unwrap();
    Campaign::new(spec(), &parallel_out).jobs(4).run().unwrap();

    let serial = Comparison::from_store(&serial_out, CompareOptions::default()).unwrap();
    let parallel = Comparison::from_store(&parallel_out, CompareOptions::default()).unwrap();
    serial.write(&serial_out).unwrap();
    parallel.write(&parallel_out).unwrap();
    for file in FILES {
        assert_eq!(
            read(&serial_out.join(file)),
            read(&parallel_out.join(file)),
            "{file} must not depend on the campaign's worker count"
        );
    }

    // re-invoking the comparator reproduces the same bytes
    let before: Vec<String> = FILES.iter().map(|f| read(&serial_out.join(f))).collect();
    Comparison::from_store(&serial_out, CompareOptions::default())
        .unwrap()
        .write(&serial_out)
        .unwrap();
    for (file, text) in FILES.iter().zip(&before) {
        assert_eq!(&read(&serial_out.join(file)), text, "{file} must be reproducible");
    }

    // the content is what the acceptance criteria ask for: per-seed paired
    // deltas + bootstrap CIs per cell, energy only where the addon ran
    let deltas = &before[0];
    assert!(deltas.starts_with(Comparison::DELTAS_CSV_HEADER));
    for metric in ["slowdown", "wait", "makespan"] {
        assert!(deltas.contains(&format!(",baseline,{metric},SJF-FF,FIFO-FF,3,")), "{deltas}");
        assert!(deltas.contains(&format!(",power,{metric},SJF-FF,FIFO-FF,3,")), "{deltas}");
    }
    assert!(deltas.contains(",power,energy,"), "power scenario pairs energy:\n{deltas}");
    assert!(!deltas.contains(",baseline,energy,"), "no energy without the addon:\n{deltas}");
    assert!(serial.warnings.is_empty(), "{:?}", serial.warnings);
}

#[test]
fn pairing_is_by_seed_not_store_order() {
    let tmp = tempfile::tempdir().unwrap();
    let out = tmp.path().join("out");
    Campaign::new(spec(), &out).jobs(2).run().unwrap();
    let reference = Comparison::from_store(&out, CompareOptions::default()).unwrap();

    // shuffle the stored run order on disk: reverse `runs` inside
    // index.json (write_index would re-sort, so edit the document itself)
    let index_path = out.join("index.json");
    let doc = Json::parse(&read(&index_path)).unwrap();
    let Json::Obj(mut m) = doc else { panic!("index.json is an object") };
    let Some(Json::Arr(runs)) = m.remove("runs") else { panic!("index.json has runs") };
    assert!(runs.len() >= 2);
    m.insert("runs".to_string(), Json::Arr(runs.into_iter().rev().collect()));
    std::fs::write(&index_path, Json::Obj(m).to_string_pretty()).unwrap();

    let shuffled = Comparison::from_store(&out, CompareOptions::default()).unwrap();
    assert_eq!(reference.deltas_csv(), shuffled.deltas_csv());
    assert_eq!(reference.ranks_csv(), shuffled.ranks_csv());
    assert_eq!(reference.report_md(), shuffled.report_md());
}

#[test]
fn missing_repetition_drops_the_seed_with_a_warning_not_a_panic() {
    let tmp = tempfile::tempdir().unwrap();
    let out = tmp.path().join("out");
    let report = Campaign::new(spec(), &out).jobs(2).run().unwrap();

    // drop one SJF-FF repetition from the store and rebuild the index from
    // the remaining manifests (as a sharded/partial re-aggregation would)
    let victim = report
        .records
        .iter()
        .find(|r| r.dispatcher == "SJF-FF" && r.scenario == "baseline" && r.seed == 2)
        .unwrap();
    std::fs::remove_dir_all(run_dir(&out, &victim.run_id)).unwrap();
    let kept: Vec<_> =
        report.records.iter().filter(|r| r.run_id != victim.run_id).cloned().collect();
    let idx = load_index(&out).unwrap();
    accasim::campaign::store::write_index(&out, &idx.campaign, idx.spec_hash, &kept).unwrap();

    let cmp = Comparison::from_store(&out, CompareOptions::default()).unwrap();
    assert!(
        cmp.warnings.iter().any(|w| w.contains("SJF-FF") && w.contains("[2]")),
        "missing repetition must be reported: {:?}",
        cmp.warnings
    );
    let d = cmp
        .deltas
        .iter()
        .find(|d| d.scenario == "baseline" && d.metric == Metric::Slowdown)
        .unwrap();
    assert_eq!(d.seeds, vec![1, 3], "seed 2 drops from the baseline-cell pairing");
    let full = cmp
        .deltas
        .iter()
        .find(|d| d.scenario == "power" && d.metric == Metric::Slowdown)
        .unwrap();
    assert_eq!(full.seeds, vec![1, 2, 3], "the intact cell keeps all pairs");
    assert!(cmp.report_md().contains("SJF-FF is missing seed(s) [2]"));
}

#[test]
fn single_dispatcher_store_is_a_clear_error() {
    let tmp = tempfile::tempdir().unwrap();
    let out = tmp.path().join("out");
    let mut solo = CampaignSpec::new("solo");
    solo.add_trace("seth", 0.0005).add_system_trace("seth").add_dispatcher("FIFO-FF");
    Campaign::new(solo, &out).run().unwrap();
    let err = Comparison::from_store(&out, CompareOptions::default()).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("single dispatcher"), "{msg}");
    assert!(msg.contains("FIFO-FF"), "names the lone dispatcher: {msg}");
}

#[test]
fn baseline_and_metric_selection() {
    let tmp = tempfile::tempdir().unwrap();
    let out = tmp.path().join("out");
    Campaign::new(spec(), &out).jobs(2).run().unwrap();
    let cmp = Comparison::from_store(
        &out,
        CompareOptions {
            baseline: Some("SJF-FF".to_string()),
            metrics: vec![Metric::Wait],
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(cmp.baseline, "SJF-FF");
    assert!(cmp.deltas.iter().all(|d| d.metric == Metric::Wait));
    assert!(cmp.deltas.iter().all(|d| d.dispatcher == "FIFO-FF"));
    // CIs are bona fide intervals around the point estimate
    for d in &cmp.deltas {
        assert!(d.ci.lo <= d.mean_delta && d.mean_delta <= d.ci.hi, "{d:?}");
    }
}
