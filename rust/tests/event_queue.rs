//! Equivalence of the unified heap event loop against the seed's two-
//! `BTreeMap` reference loop: on workloads without addons the refactor must
//! be behaviour-preserving — identical `JobRecord`s out, identical
//! completed/rejected counts — while fixing the duplicate-time-point and
//! starvation defects that only addons and zero-duration jobs expose.

use accasim::config::SysConfig;
use accasim::dispatch::{dispatcher_from_label, RunningInfo, SystemView};
use accasim::output::{JobRecord, OutputCollector};
use accasim::resources::ResourceManager;
use accasim::sim::{SimOptions, Simulator};
use accasim::testkit::{arb_jobs, check};
use accasim::util::idhash::IdHashMap;
use accasim::workload::{Job, JobId};
use std::collections::{BTreeMap, HashSet, VecDeque};

/// The seed event loop, preserved as a test oracle: two time-indexed
/// `BTreeMap`s (pending submissions, completions), bulk-reject at drain.
fn reference_run(jobs: Vec<Job>, sys: &SysConfig, label: &str) -> (Vec<JobRecord>, u64, u64) {
    let mut dispatcher = dispatcher_from_label(label).unwrap();
    let mut rm = ResourceManager::from_config(sys);
    let mut pending: BTreeMap<u64, Vec<Job>> = BTreeMap::new();
    {
        let mut sorted = jobs;
        sorted.sort_by_key(|j| (j.submit, j.id));
        for j in sorted {
            pending.entry(j.submit).or_default().push(j);
        }
    }
    let mut table: IdHashMap<Job> = IdHashMap::default();
    let mut queue: VecDeque<JobId> = VecDeque::new();
    let mut completions: BTreeMap<u64, Vec<JobId>> = BTreeMap::new();
    let mut starts: IdHashMap<u64> = IdHashMap::default();
    let extra = BTreeMap::new();
    let mut records = Vec::new();
    let (mut completed, mut rejected) = (0u64, 0u64);
    loop {
        let now = match (pending.keys().next().copied(), completions.keys().next().copied()) {
            (Some(s), Some(c)) => s.min(c),
            (Some(s), None) => s,
            (None, Some(c)) => c,
            (None, None) => {
                for id in std::mem::take(&mut queue) {
                    table.remove(&id);
                    rejected += 1;
                }
                break;
            }
        };
        if let Some(done) = completions.remove(&now) {
            for id in done {
                let job = table.remove(&id).unwrap();
                let start = starts.remove(&id).unwrap();
                rm.release(&job).unwrap();
                let wait = start - job.submit;
                records.push(JobRecord {
                    id,
                    submit: job.submit,
                    start,
                    end: now,
                    slots: job.slots,
                    wait,
                    slowdown: job.slowdown(wait),
                });
                completed += 1;
            }
        }
        if let Some(subs) = pending.remove(&now) {
            for job in subs {
                if !rm.can_ever_host(&job) {
                    rejected += 1;
                    continue;
                }
                queue.push_back(job.id);
                table.insert(job.id, job);
            }
        }
        let decision = {
            let queue_jobs: Vec<&Job> = queue.iter().map(|id| &table[id]).collect();
            let running: Vec<RunningInfo> = starts
                .iter()
                .map(|(id, &start)| RunningInfo { job: &table[id], start })
                .collect();
            let view = SystemView { now, queue: queue_jobs, running, extra: &extra };
            dispatcher.dispatch(&view, &mut rm)
        };
        for (id, _alloc) in &decision.started {
            let completion = table[id].completion_at(now);
            starts.insert(*id, now);
            completions.entry(completion).or_default().push(*id);
        }
        for id in &decision.rejected {
            table.remove(id);
            rejected += 1;
        }
        let remove: HashSet<JobId> = decision
            .started
            .iter()
            .map(|(id, _)| *id)
            .chain(decision.rejected.iter().copied())
            .collect();
        if !remove.is_empty() {
            queue.retain(|q| !remove.contains(q));
        }
    }
    (records, completed, rejected)
}

fn heap_run(jobs: Vec<Job>, sys: SysConfig, label: &str) -> (Vec<JobRecord>, u64, u64) {
    let d = dispatcher_from_label(label).unwrap();
    let opts = SimOptions {
        output: OutputCollector::in_memory(true, true),
        mem_sample_secs: 0,
        ..Default::default()
    };
    let mut sim = Simulator::from_jobs(jobs, sys, d, opts);
    let out = sim.run().unwrap();
    (out.jobs.clone(), out.jobs_completed, out.jobs_rejected)
}

/// Randomized workloads through both loops, record-for-record. Dispatchers
/// whose decisions depend only on queue order and resource-manager state
/// (not on running-set iteration order) make the oracle exact.
#[test]
fn heap_loop_matches_btreemap_reference() {
    const LABELS: &[&str] = &["FIFO-FF", "SJF-BF", "LJF-FF"];
    check("heap-vs-btreemap", 0x5EED, 40, |rng| {
        let nodes = rng.range_u64(1, 10);
        let sys = SysConfig::homogeneous(
            "eq",
            nodes,
            &[("core", rng.range_u64(1, 16)), ("mem", rng.range_u64(8, 64))],
            0,
        );
        let n = rng.range_u64(1, 70) as usize;
        let jobs = arb_jobs(rng, n, 16, 2);
        let label = LABELS[rng.range_u64(0, LABELS.len() as u64 - 1) as usize];

        let (mut ref_recs, ref_done, ref_rej) = reference_run(jobs.clone(), &sys, label);
        let (mut heap_recs, heap_done, heap_rej) = heap_run(jobs, sys, label);

        assert_eq!(heap_done, ref_done, "{label}: completed diverged");
        assert_eq!(heap_rej, ref_rej, "{label}: rejected diverged");
        ref_recs.sort_by_key(|r| r.id);
        heap_recs.sort_by_key(|r| r.id);
        assert_eq!(heap_recs.len(), ref_recs.len());
        for (h, r) in heap_recs.iter().zip(&ref_recs) {
            assert_eq!(h, r, "{label}: record diverged for job {}", h.id);
        }
    });
}

/// The one intended divergence from the reference: equal-timestamp events
/// coalesce into a single time point, so the heap loop emits exactly one
/// perf record per timestamp even when zero-duration jobs complete within
/// the timestamp they started.
#[test]
fn coalescing_emits_one_perf_record_per_timestamp() {
    check("coalesce-perf", 0xC0A1, 30, |rng| {
        let sys = SysConfig::homogeneous("eq", 2, &[("core", 4)], 0);
        let n = rng.range_u64(5, 50) as usize;
        let mut jobs = arb_jobs(rng, n, 4, 1);
        for j in &mut jobs {
            j.submit = rng.range_u64(0, 20); // dense bursts
            if rng.range_u64(0, 1) == 1 {
                j.duration = 0; // force same-timestamp completions
            }
        }
        let d = dispatcher_from_label("FIFO-FF").unwrap();
        let opts = SimOptions {
            output: OutputCollector::in_memory(true, true),
            mem_sample_secs: 0,
            ..Default::default()
        };
        let mut sim = Simulator::from_jobs(jobs, sys, d, opts);
        let out = sim.run().unwrap();
        assert_eq!(out.jobs_completed + out.jobs_rejected, n as u64);
        for w in out.perf.windows(2) {
            assert!(
                w[0].t < w[1].t,
                "duplicate time point at t={} (perf must be strictly increasing)",
                w[1].t
            );
        }
    });
}
