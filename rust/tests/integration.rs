//! Cross-module integration tests: full simulations over synthesized SWF
//! traces, the experimentation tool end-to-end, baseline loader ordering,
//! generator round-trips, and the Figure-shape expectations of §7.

use accasim::baselines::{run_rejecting, LoaderMode};
use accasim::config::SysConfig;
use accasim::dispatch::dispatcher_from_label;
use accasim::experiment::Experiment;
use accasim::generator::{RequestLimits, WorkloadGenerator};
use accasim::output::OutputCollector;
use accasim::plotdata::{submission_distributions, PlotFactory, PlotKind};
use accasim::sim::{SimOptions, SimOutput, Simulator};
use accasim::stats::ks_statistic;
use accasim::testutil as tempfile;
use accasim::traces::{self, SETH};
use std::collections::BTreeMap;

fn run_label(swf: &std::path::Path, sys: &SysConfig, label: &str) -> SimOutput {
    let d = dispatcher_from_label(label).unwrap();
    let opts = SimOptions {
        output: OutputCollector::in_memory(true, true),
        ..Default::default()
    };
    let mut sim = Simulator::new(swf, sys.clone(), d, opts).unwrap();
    sim.run().unwrap()
}

/// All eight paper dispatchers complete a Seth-slice end to end.
#[test]
fn all_dispatchers_complete_seth_slice() {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("seth.swf");
    SETH.synthesize(&swf, 0.002, 3).unwrap(); // ~400 jobs
    let sys = SETH.sys_config();
    let mut completions = Vec::new();
    for s in ["FIFO", "SJF", "LJF", "EBF"] {
        for a in ["FF", "BF"] {
            let out = run_label(&swf, &sys, &format!("{s}-{a}"));
            assert!(
                out.jobs_completed + out.jobs_rejected == 406,
                "{s}-{a}: {} + {}",
                out.jobs_completed,
                out.jobs_rejected
            );
            assert!(out.jobs_completed > 380, "{s}-{a} completed {}", out.jobs_completed);
            completions.push((format!("{s}-{a}"), out));
        }
    }
    // Fig 10 shape: SJF/EBF mean slowdown ≤ FIFO/LJF mean slowdown.
    let mean = |l: &str| {
        completions.iter().find(|(lab, _)| lab == l).unwrap().1.avg_slowdown()
    };
    let best = mean("SJF-FF").min(mean("EBF-FF"));
    let worst = mean("FIFO-FF").max(mean("LJF-FF"));
    assert!(
        best <= worst + 1e-9,
        "expected SJF/EBF ≤ FIFO/LJF slowdown: best {best} vs worst {worst}"
    );
}

/// The experimentation tool writes all four figure CSVs with all dispatchers.
#[test]
fn experiment_tool_end_to_end() {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("w.swf");
    SETH.synthesize(&swf, 0.001, 9).unwrap();
    let mut e = Experiment::new("it", &swf, SETH.sys_config());
    e.out_dir = dir.path().join("out");
    e.gen_dispatchers(&["FIFO", "SJF", "LJF", "EBF"], &["FF", "BF"]);
    let res = e.run_simulation().unwrap();
    assert_eq!(res.runs.len(), 8);
    for p in &res.plots {
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.lines().count() >= 9, "{}: expected 8 dispatcher rows", p.display());
    }
}

/// Table 1 memory ordering: incremental ≤ eager-light ≤ eager-heavy growth.
#[test]
fn baseline_memory_ordering() {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("w.swf");
    SETH.synthesize(&swf, 0.05, 4).unwrap(); // ~10k jobs
    let sys = SETH.sys_config();
    // measure in our own subprocess-free way: relative max growth
    let inc = run_rejecting(&swf, &sys, LoaderMode::Incremental).unwrap();
    let light = run_rejecting(&swf, &sys, LoaderMode::EagerLight).unwrap();
    let heavy = run_rejecting(&swf, &sys, LoaderMode::EagerHeavy).unwrap();
    assert_eq!(inc.jobs, light.jobs);
    assert_eq!(light.jobs, heavy.jobs);
    // RSS high-water persists across measurements in one process, so only
    // the monotone ordering along increasing footprint is asserted.
    assert!(
        heavy.max_rss_kb >= light.max_rss_kb,
        "heavy {} < light {}",
        heavy.max_rss_kb,
        light.max_rss_kb
    );
    assert!(
        light.max_rss_kb >= inc.max_rss_kb,
        "light {} < incremental {}",
        light.max_rss_kb,
        inc.max_rss_kb
    );
}

/// Generator round trip (Figs 14–17): generated submissions and GFLOPs
/// track the seed distributions.
#[test]
fn generator_tracks_seed_trace() {
    let dir = tempfile::tempdir().unwrap();
    let seed_swf = dir.path().join("seed.swf");
    SETH.synthesize(&seed_swf, 0.01, 5).unwrap(); // ~2k jobs
    let perf: BTreeMap<String, f64> = [("core".to_string(), 1.667)].into_iter().collect();
    let limits = RequestLimits::new(&[("core", 1), ("mem", 1)], &[("core", 128), ("mem", 256)]);
    let mut g =
        WorkloadGenerator::from_swf(&seed_swf, SETH.sys_config(), perf, limits, 42).unwrap();
    let rep = g.generate_jobs(5_000, dir.path().join("gen.swf")).unwrap();

    // seed submissions
    let seed_times: Vec<u64> = accasim::workload::SwfReader::open(&seed_swf)
        .unwrap()
        .map(|r| r.unwrap().submit_time as u64)
        .collect();
    let (sh, sd_, _) = submission_distributions(&seed_times);
    let (gh, gd, _) = submission_distributions(&rep.times);
    // hourly/daily shares: L1 distance below generous thresholds
    let l1h: f64 = sh.iter().zip(&gh).map(|(a, b)| (a - b).abs()).sum();
    let l1d: f64 = sd_.iter().zip(&gd).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1h < 0.5, "hourly L1 {l1h}");
    assert!(l1d < 0.5, "daily L1 {l1d}");

    // the generated dataset must simulate cleanly
    let out = run_label(&dir.path().join("gen.swf"), &SETH.sys_config(), "SJF-FF");
    assert!(out.jobs_completed > 4_500);
    assert!(rep.gflops.iter().all(|g| *g > 0.0));
}

/// XLA metrics path equals the Rust stats path on real simulation output
/// (plotdata cross-check; skipped without artifacts).
#[test]
fn xla_metrics_match_rust_on_sim_output() {
    if !std::path::Path::new("artifacts/metrics.hlo.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let engine = accasim::runtime::Engine::with_artifacts("artifacts").unwrap();
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("w.swf");
    SETH.synthesize(&swf, 0.002, 8).unwrap();
    let out = run_label(&swf, &SETH.sys_config(), "FIFO-FF");
    let b = accasim::runtime::shapes::MET_B;
    let mut wait = vec![0f32; b];
    let mut dur = vec![0f32; b];
    let mut mask = vec![0f32; b];
    for (i, rec) in out.jobs.iter().take(b).enumerate() {
        wait[i] = rec.wait as f32;
        dur[i] = (rec.end - rec.start) as f32;
        mask[i] = 1.0;
    }
    let res = engine
        .execute_f32(
            "metrics",
            &[(&wait, &[b as i64]), (&dur, &[b as i64]), (&mask, &[b as i64])],
        )
        .unwrap();
    let n = out.jobs.len().min(b);
    for (i, rec) in out.jobs.iter().take(n).enumerate() {
        assert!(
            (res[0][i] as f64 - rec.slowdown).abs() < 1e-3 * rec.slowdown,
            "job {i}: xla {} vs rust {}",
            res[0][i],
            rec.slowdown
        );
    }
    assert_eq!(res[2][0] as usize, n, "summary count");
}

/// Figure 12/13 shape: EBF spends more dispatch time than FIFO, and its
/// per-decision time grows with queue size.
#[test]
fn ebf_dispatch_cost_dominates() {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("w.swf");
    // congested slice → long queues
    SETH.synthesize(&swf, 0.005, 6).unwrap();
    let sys = SETH.sys_config();
    let fifo = run_label(&swf, &sys, "FIFO-FF");
    let ebf = run_label(&swf, &sys, "EBF-FF");
    let per_point = |o: &SimOutput| o.dispatch_ns as f64 / o.time_points.max(1) as f64;
    assert!(
        per_point(&ebf) > per_point(&fifo),
        "EBF {} ≤ FIFO {} ns/point",
        per_point(&ebf),
        per_point(&fifo)
    );

    let mut pf = PlotFactory::new();
    pf.add_run("EBF-FF", vec![ebf]);
    let rows = pf.scalability_rows(10);
    assert!(!rows.is_empty());
}

/// materialize() produces loadable config + workload pairs for all traces.
#[test]
fn materialized_traces_simulate() {
    let dir = tempfile::tempdir().unwrap();
    for spec in traces::ALL {
        let scale = 100.0 / spec.jobs as f64; // ~100 jobs each
        let (swf, cfg) = traces::materialize(spec, dir.path(), scale, 2).unwrap();
        let sys = SysConfig::from_json_file(&cfg).unwrap();
        let out = run_label(&swf, &sys, "FIFO-FF");
        assert!(
            out.jobs_completed + out.jobs_rejected >= 99,
            "{}: {}",
            spec.name,
            out.jobs_completed
        );
    }
}

/// KS sanity: a trace is similar to itself and different seeds stay similar
/// in distribution (calibrates the Fig 14–17 comparison metric).
#[test]
fn trace_distributions_stable_across_seeds() {
    let dir = tempfile::tempdir().unwrap();
    let (a, b) = (dir.path().join("a.swf"), dir.path().join("b.swf"));
    SETH.synthesize(&a, 0.005, 1).unwrap();
    SETH.synthesize(&b, 0.005, 2).unwrap();
    let durs = |p: &std::path::Path| -> Vec<f64> {
        accasim::workload::SwfReader::open(p)
            .unwrap()
            .map(|r| r.unwrap().run_time as f64)
            .collect()
    };
    let ks = ks_statistic(&durs(&a), &durs(&b));
    assert!(ks < 0.08, "duration KS across seeds = {ks}");
}

/// Fig 8/9 monitoring renders on a real post-simulation state.
#[test]
fn monitoring_renders() {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("w.swf");
    SETH.synthesize(&swf, 0.001, 7).unwrap();
    let sys = SETH.sys_config();
    let d = dispatcher_from_label("FIFO-FF").unwrap();
    let mut sim = Simulator::new(&swf, sys, d, SimOptions::default()).unwrap();
    let out = sim.run().unwrap();
    let status = accasim::monitor::SystemStatus::gather(
        out.last_completion,
        0,
        0,
        0,
        out.jobs_completed,
        out.jobs_rejected,
        sim.resource_manager(),
        out.cpu_ms,
    );
    let panel = status.render();
    assert!(panel.contains("completed=203"));
    let viz = accasim::monitor::render_utilization(sim.resource_manager(), 60);
    assert!(viz.contains("core"));
    let mut pf = PlotFactory::new();
    pf.add_run("FIFO-FF", vec![out]);
    assert!(pf.render_boxes(PlotKind::Slowdown, 40).contains("FIFO-FF"));
}
