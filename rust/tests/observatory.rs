//! Observatory invariants (DESIGN.md §Observability).
//!
//! Three families of guarantees around the time-series consumer and the
//! campaign-wide aggregation pipeline:
//!
//! 1. **Observation-only** — a simulation driven with a
//!    [`TimeSeriesRecorder`] consuming the event log produces
//!    byte-identical job and perf records to one without, across the
//!    dispatcher families and under a failure storm.
//! 2. **Determinism** — the LTTB downsampler and the whole
//!    `timeseries.csv` artifact are byte-identical across re-runs, and
//!    the observatory aggregate is byte-identical across loader thread
//!    counts (`--jobs`) and re-invocations over one finished store.
//! 3. **Regression detection** — `--baseline` flags an injected
//!    dispatch-p99 regression in a store fixture, while a store checked
//!    against itself passes clean.

use accasim::addons::FailureInjector;
use accasim::campaign::{load_index, run_dir, Campaign, CampaignSpec, Observatory};
use accasim::config::SysConfig;
use accasim::dispatch::dispatcher_from_label;
use accasim::output::OutputCollector;
use accasim::rng::Pcg64;
use accasim::sim::{SimOptions, SimOutput, Simulator, Step};
use accasim::telemetry::{Telemetry, TimeSeriesRecorder, TIMESERIES_FILE};
use accasim::testkit::arb_jobs;
use accasim::testutil as tempfile;
use accasim::util::json::Json;
use accasim::workload::Job;
use std::collections::BTreeMap;
use std::path::Path;

/// The deterministic portion of a run, as `rust/tests/telemetry.rs`
/// renders it: full job records plus the timing-free perf columns.
fn deterministic_bytes(out: &SimOutput) -> String {
    let mut s = String::from("jobs.csv\n");
    for j in &out.jobs {
        s.push_str(&j.to_csv());
        s.push('\n');
    }
    s.push_str("perf(t,queue,running,started)\n");
    for p in &out.perf {
        s.push_str(&format!("{},{},{},{}\n", p.t, p.queue_len, p.running, p.started));
    }
    s.push_str(&format!(
        "completed={} rejected={} makespan={} slowdown_sum={} wait_sum={} max_queue={}\n",
        out.jobs_completed,
        out.jobs_rejected,
        out.makespan,
        out.slowdown_sum,
        out.wait_sum,
        out.max_queue
    ));
    s
}

fn opts_with(tel: Telemetry, addons: Vec<Box<dyn accasim::addons::AdditionalData>>) -> SimOptions {
    SimOptions {
        output: OutputCollector::in_memory(true, true),
        mem_sample_secs: 0,
        telemetry: tel,
        addons,
        ..Default::default()
    }
}

/// Run a simulation step-by-step with a [`TimeSeriesRecorder`] attached
/// as an event-log consumer, sampling resource state after every
/// advanced point — the exact loop the campaign worker runs.
fn record_run(
    jobs: Vec<Job>,
    sys: SysConfig,
    label: &str,
    opts: SimOptions,
    budget: usize,
) -> (SimOutput, TimeSeriesRecorder) {
    let mut sim = Simulator::from_jobs(jobs, sys, dispatcher_from_label(label).unwrap(), opts);
    let cursor = sim.register_consumer();
    let mut rec = TimeSeriesRecorder::with_budget(sim.resource_manager().resource_types(), budget);
    loop {
        let step = sim.step().expect("step");
        sim.drain_events(cursor, |ev| {
            rec.apply(ev);
            Ok(())
        })
        .expect("drain");
        match step {
            Step::Advanced(_) => rec.sample(sim.resource_manager(), sim.extra()),
            Step::Idle | Step::Done => break,
        }
    }
    (sim.finish().expect("finish"), rec)
}

/// Attaching the recorder (with telemetry on, as campaigns run it) must
/// not change a single deterministic byte, for every dispatcher family.
#[test]
fn recorder_is_observation_only_across_dispatchers() {
    let mut rng = Pcg64::new(0x0B5E);
    let jobs = arb_jobs(&mut rng, 120, 12, 3);
    let sys = SysConfig::homogeneous("obs", 6, &[("core", 8), ("gpu", 1), ("mem", 64)], 0);
    for label in ["FIFO-FF", "SJF-BF", "LJF-WF", "EBF-FF", "CBF-FF", "FIFO_RND-FF"] {
        let mut plain = Simulator::from_jobs(
            jobs.clone(),
            sys.clone(),
            dispatcher_from_label(label).unwrap(),
            opts_with(Telemetry::disabled(), vec![]),
        );
        let off = plain.run().expect("plain run");
        let (on, rec) = record_run(
            jobs.clone(),
            sys.clone(),
            label,
            opts_with(Telemetry::enabled(), vec![]),
            accasim::telemetry::DEFAULT_POINT_BUDGET,
        );
        assert_eq!(
            deterministic_bytes(&off),
            deterministic_bytes(&on),
            "{label}: the time-series recorder changed simulation results"
        );
        assert!(off.jobs_completed > 0, "{label}: degenerate case");
        assert_eq!(
            rec.raw_points() as usize,
            on.time_points as usize,
            "{label}: one PointClosed event per time point"
        );
        // every start is classified exactly once
        let s = rec.summary();
        let starts = s.get("head_starts").unwrap().as_u64().unwrap()
            + s.get("backfill_starts").unwrap().as_u64().unwrap();
        assert_eq!(starts as usize, on.jobs_completed as usize, "{label}: start classification");
    }
}

/// Same guarantee under a failure storm: down/up transitions churn the
/// availability index and wake addons while the recorder derives the
/// down-node series from the sampled state.
#[test]
fn recorder_is_observation_only_under_a_failure_storm() {
    let mut rng = Pcg64::new(0x5709);
    let jobs = arb_jobs(&mut rng, 80, 8, 2);
    let sys = SysConfig::homogeneous("obsf", 4, &[("core", 8), ("mem", 64)], 0);
    let storm = || -> Vec<Box<dyn accasim::addons::AdditionalData>> {
        vec![Box::new(FailureInjector::new(vec![
            (0, 100, 5_000),
            (1, 2_000, 20_000),
            (2, 100, 3_000),
        ]))]
    };
    let mut plain = Simulator::from_jobs(
        jobs.clone(),
        sys.clone(),
        dispatcher_from_label("FIFO-FF").unwrap(),
        opts_with(Telemetry::disabled(), storm()),
    );
    let off = plain.run().expect("plain run");
    let (on, rec) = record_run(
        jobs,
        sys,
        "FIFO-FF",
        opts_with(Telemetry::enabled(), storm()),
        accasim::telemetry::DEFAULT_POINT_BUDGET,
    );
    assert_eq!(deterministic_bytes(&off), deterministic_bytes(&on));
    assert_eq!(off.addon_wakes, on.addon_wakes, "wake path must not see the recorder");
    let s = rec.summary();
    assert!(
        s.get("down_nodes_peak").unwrap().as_u64().unwrap() >= 1,
        "failure windows must surface in the sampled down-node series: {s:?}"
    );
}

/// The written artifact is deterministic even when the downsampler has
/// to work: a small budget forces mid-run compressions, and two
/// identical runs must still produce byte-identical `timeseries.csv`.
#[test]
fn timeseries_artifact_is_byte_identical_across_reruns() {
    let tmp = tempfile::tempdir().unwrap();
    let mut rng = Pcg64::new(0xD5A7);
    let jobs = arb_jobs(&mut rng, 150, 10, 2);
    let sys = SysConfig::homogeneous("ts", 4, &[("core", 8), ("mem", 64)], 0);
    let write_once = |dir: &Path| -> (String, Json) {
        let (_, mut rec) = record_run(
            jobs.clone(),
            sys.clone(),
            "SJF-BF",
            opts_with(Telemetry::enabled(), vec![]),
            16,
        );
        let p = rec.write(dir).unwrap();
        assert_eq!(p, dir.join(TIMESERIES_FILE));
        (std::fs::read_to_string(p).unwrap(), rec.summary())
    };
    let (a, sa) = write_once(tmp.path());
    let dir_b = tmp.path().join("again");
    std::fs::create_dir_all(&dir_b).unwrap();
    let (b, sb) = write_once(&dir_b);
    assert_eq!(a, b, "downsampled artifact must be reproducible byte for byte");
    assert_eq!(sa.to_string_compact(), sb.to_string_compact());
    assert!(
        sa.get("compressions").unwrap().as_u64().unwrap() > 0,
        "the tiny budget must actually exercise LTTB: {sa:?}"
    );
    let lines: Vec<&str> = a.lines().collect();
    assert!(lines[0].starts_with("t,queue,running,started,head_starts,backfill_starts"));
    assert!(lines.len() - 1 <= 16, "{} rows exceed the budget", lines.len() - 1);
}

fn tiny_spec(name: &str) -> CampaignSpec {
    let mut s = CampaignSpec::new(name);
    s.add_trace("seth", 0.0005).add_system_trace("seth");
    s.add_dispatcher("FIFO-FF").add_dispatcher("SJF-BF");
    s.seeds = vec![1, 2];
    s
}

/// One finished store, aggregated serially, with 3 loader threads, and
/// then again: every observatory artifact must come out byte-identical.
#[test]
fn observatory_is_byte_identical_across_jobs_and_reinvocation() {
    let tmp = tempfile::tempdir().unwrap();
    let out = tmp.path().join("out");
    let report = Campaign::new(tiny_spec("obsstore"), &out).run().unwrap();
    assert_eq!(report.records.len(), 4);

    let serial = Observatory::from_store(&out).unwrap();
    let threaded = Observatory::from_store_with_jobs(&out, 3).unwrap();
    assert_eq!(serial.telemetry_csv(), threaded.telemetry_csv());
    assert_eq!(serial.report_md(), threaded.report_md());
    assert_eq!(serial.report_html(), threaded.report_html());

    // the aggregate reads observed spans and manifests
    assert_eq!(serial.cells.len(), 2, "one row per dispatcher");
    for c in &serial.cells {
        assert_eq!((c.runs, c.with_telemetry), (2, 2), "{}: campaigns observe by default", c.dispatcher);
        assert!(c.dispatch_p50_ns > 0.0, "{}: dispatch spans aggregated", c.dispatcher);
        assert!(c.points_per_s > 0.0, "{}: throughput from run.json measure", c.dispatcher);
        assert!(!c.queue_series.is_empty(), "{}: sparkline series loaded", c.dispatcher);
    }

    // re-invocation over the unchanged store rewrites identical bytes
    serial.write(&out).unwrap();
    serial.write_html(&out).unwrap();
    let read = |name: &str| std::fs::read_to_string(out.join("observatory").join(name)).unwrap();
    let (csv, md, html) = (read("telemetry.csv"), read("report.md"), read("observatory.html"));
    let again = Observatory::from_store(&out).unwrap();
    again.write(&out).unwrap();
    again.write_html(&out).unwrap();
    assert_eq!(csv, read("telemetry.csv"));
    assert_eq!(md, read("report.md"));
    assert_eq!(html, read("observatory.html"));
    assert!(
        !html.contains("src=") && !html.contains("href=") && !html.contains("<script"),
        "dashboard must stay self-contained"
    );
}

/// The regression fixture: a store checked against itself passes; the
/// same store with one run's dispatch p99 inflated a hundredfold is
/// flagged on exactly that dispatcher's cell.
#[test]
fn baseline_check_flags_an_injected_p99_regression() {
    let tmp = tempfile::tempdir().unwrap();
    let out = tmp.path().join("out");
    Campaign::new(tiny_spec("obsbase"), &out).run().unwrap();
    let baseline = Observatory::from_store(&out).unwrap();
    assert!(
        baseline.check_against(&baseline, 0.25).is_empty(),
        "a store checked against itself must pass clean"
    );

    // inject the regression: multiply one FIFO-FF run's dispatch p99
    let idx = load_index(&out).unwrap();
    let victim = idx
        .records
        .iter()
        .find(|r| r.dispatcher == "FIFO-FF")
        .expect("store has FIFO-FF runs");
    let tel_path = run_dir(&out, &victim.run_id).join("telemetry.json");
    let mut doc = Json::parse(&std::fs::read_to_string(&tel_path).unwrap()).unwrap();
    fn obj(j: &mut Json) -> &mut BTreeMap<String, Json> {
        match j {
            Json::Obj(m) => m,
            other => panic!("expected object, got {other:?}"),
        }
    }
    let spans = obj(obj(&mut doc).get_mut("spans").expect("spans block"));
    let cycle = obj(spans.get_mut("dispatch_cycle").expect("dispatch span"));
    match cycle.get_mut("p99_ns").expect("p99") {
        Json::Num(v) => *v *= 100.0,
        other => panic!("p99_ns not numeric: {other:?}"),
    }
    std::fs::write(&tel_path, doc.to_string_pretty()).unwrap();

    let current = Observatory::from_store(&out).unwrap();
    let regs = current.check_against(&baseline, 0.25);
    assert!(
        regs.iter().any(|r| r.metric == "dispatch_p99_ns" && r.cell.contains("FIFO-FF")),
        "injected p99 regression must be flagged: {regs:?}"
    );
    assert!(
        regs.iter().all(|r| r.cell.contains("FIFO-FF")),
        "the untouched dispatcher must pass: {regs:?}"
    );
    let csv = Observatory::regressions_csv(&regs);
    assert!(csv.starts_with(Observatory::REGRESSIONS_CSV_HEADER));
    assert!(csv.contains("dispatch_p99_ns"), "{csv}");
}
