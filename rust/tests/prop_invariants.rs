//! Property-based coordinator invariants (testkit-driven; see
//! `rust/src/testkit.rs`). Each property runs many randomized cases with a
//! reported replay seed on failure.

use accasim::config::SysConfig;
use accasim::dispatch::dispatcher_from_label;
use accasim::output::OutputCollector;
use accasim::resources::ResourceManager;
use accasim::rng::Pcg64;
use accasim::sim::{SimOptions, SimOutput, Simulator};
use accasim::testkit::{arb_jobs, check};
use accasim::workload::Job;

const DISPATCHERS: &[&str] = &[
    "FIFO-FF", "FIFO-BF", "SJF-FF", "SJF-BF", "LJF-FF", "LJF-BF", "EBF-FF", "EBF-BF",
];

fn arb_sys(rng: &mut Pcg64) -> SysConfig {
    SysConfig::homogeneous(
        "prop",
        rng.range_u64(1, 12),
        &[
            ("core", rng.range_u64(1, 16)),
            ("gpu", rng.range_u64(0, 2)),
            ("mem", rng.range_u64(8, 128)),
        ],
        0,
    )
}

fn run(jobs: Vec<Job>, sys: SysConfig, label: &str) -> SimOutput {
    let d = dispatcher_from_label(label).unwrap();
    let opts = SimOptions {
        output: OutputCollector::in_memory(true, true),
        mem_sample_secs: 0,
        ..Default::default()
    };
    let mut sim = Simulator::from_jobs(jobs, sys, d, opts);
    sim.run().expect("simulation completes")
}

/// Every submitted job is either completed or rejected — none lost, and the
/// simulation always terminates.
#[test]
fn prop_conservation_of_jobs() {
    check("conservation", 0xC0FFEE, 60, |rng| {
        let sys = arb_sys(rng);
        let n = rng.range_u64(1, 80) as usize;
        let jobs = arb_jobs(rng, n, 16, 3);
        let label = DISPATCHERS[rng.range_u64(0, DISPATCHERS.len() as u64 - 1) as usize];
        let out = run(jobs, sys, label);
        assert_eq!(
            out.jobs_completed + out.jobs_rejected,
            n as u64,
            "{label}: {} + {} != {n}",
            out.jobs_completed,
            out.jobs_rejected
        );
    });
}

/// No job starts before its submission; every completed job runs for exactly
/// its duration; waits/slowdowns are consistent.
#[test]
fn prop_job_timing() {
    check("timing", 0xBEEF, 60, |rng| {
        let sys = arb_sys(rng);
        let n = rng.range_u64(1, 60) as usize;
        let jobs = arb_jobs(rng, n, 16, 3);
        let by_id: std::collections::HashMap<u64, Job> =
            jobs.iter().map(|j| (j.id, j.clone())).collect();
        let label = DISPATCHERS[rng.range_u64(0, DISPATCHERS.len() as u64 - 1) as usize];
        let out = run(jobs, sys, label);
        for rec in &out.jobs {
            let j = &by_id[&rec.id];
            assert!(rec.start >= j.submit, "job {} started early", rec.id);
            assert_eq!(rec.end - rec.start, j.duration, "job {} wrong duration", rec.id);
            assert_eq!(rec.wait, rec.start - j.submit);
            let expect_sd = (rec.wait as f64 + j.duration.max(1) as f64)
                / j.duration.max(1) as f64;
            assert!((rec.slowdown - expect_sd).abs() < 1e-9);
        }
    });
}

/// At no simulation time point may the system be oversubscribed: replay the
/// completed schedule as (start, +req)/(end, −req) events and assert total
/// usage stays within capacity for every resource type.
#[test]
fn prop_no_oversubscription_via_replay() {
    check("no-oversubscription", 0xFACE, 40, |rng| {
        let sys = arb_sys(rng);
        let n = rng.range_u64(1, 60) as usize;
        let jobs = arb_jobs(rng, n, 16, 3);
        let by_id: std::collections::HashMap<u64, Job> =
            jobs.iter().map(|j| (j.id, j.clone())).collect();
        let label = DISPATCHERS[rng.range_u64(0, DISPATCHERS.len() as u64 - 1) as usize];
        let out = run(jobs, sys.clone(), label);

        let rm = ResourceManager::from_config(&sys);
        let types = rm.num_types();
        let capacity: Vec<u64> = (0..types)
            .map(|r| (0..rm.num_nodes()).map(|n| rm.node_capacity(n)[r]).sum())
            .collect();
        let mut events: Vec<(u64, i32, u64)> = Vec::new(); // (t, ±1, id)
        for rec in &out.jobs {
            events.push((rec.start, 1, rec.id));
            events.push((rec.end, -1, rec.id));
        }
        // releases before starts at equal times (the simulator completes
        // then dispatches within one time point)
        events.sort_by_key(|&(t, s, _)| (t, s));
        let mut used = vec![0i64; types];
        for (t, sign, id) in events {
            let j = &by_id[&id];
            for (r, u) in used.iter_mut().enumerate() {
                *u += sign as i64 * j.total_request(r) as i64;
                assert!(
                    *u >= 0 && *u as u64 <= capacity[r],
                    "{label}: usage {} of type {r} outside [0, {}] at t={t}",
                    *u,
                    capacity[r]
                );
            }
        }
    });
}

/// FIFO never reorders: among completed jobs, start times are monotone in
/// submission order.
#[test]
fn prop_fifo_order_preserved() {
    check("fifo-order", 0xF1F0, 40, |rng| {
        let sys = arb_sys(rng);
        let n = rng.range_u64(2, 60) as usize;
        let jobs = arb_jobs(rng, n, 16, 3);
        let out = run(jobs, sys, "FIFO-FF");
        let mut recs = out.jobs.clone();
        recs.sort_by_key(|r| (r.submit, r.id));
        for w in recs.windows(2) {
            assert!(
                w[0].start <= w[1].start,
                "FIFO reordered: job {} started {} before job {} at {}",
                w[1].id,
                w[1].start,
                w[0].id,
                w[0].start
            );
        }
    });
}

/// With exact estimates and a single reservation, EASY backfilling completes
/// the same job set without extending the schedule relative to FIFO.
#[test]
fn prop_ebf_no_worse_than_fifo_with_exact_estimates() {
    check("ebf-vs-fifo", 0xEB, 30, |rng| {
        let sys = arb_sys(rng);
        let n = rng.range_u64(2, 50) as usize;
        let mut jobs = arb_jobs(rng, n, 16, 3);
        for j in &mut jobs {
            j.req_time = j.duration.max(1); // exact estimates
        }
        let fifo = run(jobs.clone(), sys.clone(), "FIFO-FF");
        let ebf = run(jobs, sys, "EBF-FF");
        assert_eq!(fifo.jobs_completed, ebf.jobs_completed);
        assert!(
            ebf.last_completion <= fifo.last_completion,
            "EBF makespan {} > FIFO {}",
            ebf.last_completion,
            fifo.last_completion
        );
    });
}

/// SWF round-trip: parse(to_line(x)) == x for arbitrary records.
#[test]
fn prop_swf_roundtrip() {
    use accasim::workload::{parse_swf_line, SwfFields};
    check("swf-roundtrip", 0x5F5F, 200, |rng| {
        let f = SwfFields {
            job_number: rng.range_u64(1, 1 << 40) as i64,
            submit_time: rng.range_u64(0, 1 << 40) as i64,
            wait_time: rng.range_u64(0, 1 << 20) as i64 - 1,
            run_time: rng.range_u64(0, 1 << 30) as i64,
            allocated_procs: rng.range_u64(0, 4096) as i64 - 1,
            avg_cpu_time: -1,
            used_memory: rng.range_u64(0, 1 << 30) as i64 - 1,
            requested_procs: rng.range_u64(0, 4096) as i64 - 1,
            requested_time: rng.range_u64(0, 1 << 30) as i64 - 1,
            requested_memory: rng.range_u64(0, 1 << 30) as i64 - 1,
            status: rng.range_u64(0, 5) as i64 - 1,
            user_id: rng.range_u64(0, 1000) as i64,
            group_id: rng.range_u64(0, 100) as i64,
            app_id: rng.range_u64(0, 100) as i64,
            queue_id: rng.range_u64(0, 10) as i64,
            partition_id: rng.range_u64(0, 10) as i64,
            preceding_job: -1,
            think_time: -1,
        };
        let parsed = parse_swf_line(&f.to_line()).expect("roundtrip parses");
        assert_eq!(f, parsed);
    });
}

/// Simulation is deterministic: identical inputs give identical records.
#[test]
fn prop_simulation_deterministic() {
    check("determinism", 0xD3, 20, |rng| {
        let sys = arb_sys(rng);
        let n = rng.range_u64(1, 50) as usize;
        let jobs = arb_jobs(rng, n, 16, 3);
        let label = DISPATCHERS[rng.range_u64(0, DISPATCHERS.len() as u64 - 1) as usize];
        let a = run(jobs.clone(), sys.clone(), label);
        let b = run(jobs, sys, label);
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (ra, rb) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(ra, rb);
        }
    });
}

/// Estimation errors never change execution semantics: scrambled req_time
/// may reorder decisions but every job still runs its true duration (§3).
#[test]
fn prop_estimates_do_not_affect_durations() {
    check("estimates", 0xE5, 30, |rng| {
        let sys = arb_sys(rng);
        let n = rng.range_u64(1, 50) as usize;
        let mut jobs = arb_jobs(rng, n, 16, 3);
        for j in &mut jobs {
            j.req_time = rng.range_u64(1, 10_000); // wildly wrong estimates
        }
        let by_id: std::collections::HashMap<u64, u64> =
            jobs.iter().map(|j| (j.id, j.duration)).collect();
        let label = DISPATCHERS[rng.range_u64(0, DISPATCHERS.len() as u64 - 1) as usize];
        let out = run(jobs, sys, label);
        for rec in &out.jobs {
            assert_eq!(rec.end - rec.start, by_id[&rec.id]);
        }
    });
}

/// No queued job is bulk-rejected while a future addon event could still
/// free capacity: under finite failure/repair windows, every job the system
/// could ever host completes — the repair fires as an addon wake-up event
/// even when no job event falls inside the outage window. Perf timestamps
/// stay strictly increasing throughout.
#[test]
fn prop_no_starvation_under_failures() {
    use accasim::addons::FailureInjector;
    check("failure-starvation", 0xFA11, 40, |rng| {
        let nodes = rng.range_u64(2, 6);
        let sys = SysConfig::homogeneous("prop", nodes, &[("core", rng.range_u64(2, 8))], 0);
        let n = rng.range_u64(1, 40) as usize;
        let jobs = arb_jobs(rng, n, 8, 1);
        // finite failure windows over a random subset of nodes
        let plan: Vec<(u32, u64, u64)> = (0..rng.range_u64(1, nodes - 1))
            .map(|i| {
                let fail = rng.range_u64(0, 5_000);
                (i as u32, fail, fail + rng.range_u64(1, 5_000))
            })
            .collect();
        let rm = ResourceManager::from_config(&sys);
        let oversized = jobs.iter().filter(|j| !rm.can_ever_host(j)).count() as u64;
        let d = dispatcher_from_label("FIFO-FF").unwrap();
        let opts = SimOptions {
            addons: vec![Box::new(FailureInjector::new(plan))],
            output: OutputCollector::in_memory(true, true),
            mem_sample_secs: 0,
            ..Default::default()
        };
        let mut sim = Simulator::from_jobs(jobs, sys, d, opts);
        let out = sim.run().expect("simulation completes");
        assert_eq!(
            out.jobs_completed,
            n as u64 - oversized,
            "runnable jobs starved: completed {} rejected {} of {n}",
            out.jobs_completed,
            out.jobs_rejected
        );
        assert_eq!(out.jobs_rejected, oversized);
        for w in out.perf.windows(2) {
            assert!(w[0].t < w[1].t, "duplicate perf timestamp {}", w[1].t);
        }
    });
}

/// The allocation slice lists the simulator commits are internally
/// consistent: per-job slot totals always equal the request (checked by the
/// ResourceManager, surfaced here as "no panic across thousands of cases").
#[test]
fn prop_dense_contention_terminates() {
    check("dense", 0xDE05E, 20, |rng| {
        // tiny machine, many jobs, simultaneous submits — worst-case churn
        let sys = SysConfig::homogeneous("tiny", 1, &[("core", 2)], 0);
        let n = rng.range_u64(20, 120) as usize;
        let mut jobs = arb_jobs(rng, n, 2, 1);
        for j in &mut jobs {
            j.submit = rng.range_u64(0, 5); // burst
        }
        let label = DISPATCHERS[rng.range_u64(0, DISPATCHERS.len() as u64 - 1) as usize];
        let out = run(jobs, sys, label);
        assert_eq!(out.jobs_completed + out.jobs_rejected, n as u64);
    });
}
