//! Resumable-core determinism contracts (ISSUE 6 acceptance): for every
//! shipped dispatcher — and for scenarios with stateful addons (a failure
//! storm mid-flight, a power-cap schedule integrating energy) — a run that
//! is snapshotted at a midpoint, dropped, restored from the snapshot text
//! and played to completion produces `jobs.csv`/`perf.csv` byte-identical
//! to the same run left uninterrupted. Measured-time perf columns are
//! switched off (`time_dispatch: false`, `mem_sample_secs: 0`), so the
//! whole perf CSV — not just the deterministic columns — must match.

use accasim::campaign::{PowerSpec, ScenarioSpec};
use accasim::config::SysConfig;
use accasim::dispatch::{dispatcher_from_label, Dispatcher};
use accasim::output::OutputCollector;
use accasim::scenario::{Perturbation, WarpedSource};
use accasim::sim::{JobSource, SimCore, SimOptions, Step, SwfSource};
use accasim::testutil as tempfile;
use std::path::Path;

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

/// A small SWF with enough variety (durations, widths, a same-time tie)
/// that every scheduler family makes different decisions on it.
fn varied_swf(path: &Path, n: u64) {
    let mut text = String::from("; UnitTime: seconds\n");
    for i in 1..=n {
        // two jobs share each submit time so tie-break order matters
        let submit = (i - 1) / 2 * 300;
        let duration = 200 + (i % 5) * 400;
        let slots = 1 + (i % 3);
        text.push_str(&format!(
            "{i} {submit} -1 {duration} {slots} -1 -1 {slots} {} -1 1 1 1 1 1 1 -1 -1\n",
            duration * 2
        ));
    }
    std::fs::write(path, text).unwrap();
}

/// 2 nodes × 4 cores: small enough that a queue forms and backfilling,
/// capping and rejection all have something to do.
fn tiny_sys() -> SysConfig {
    SysConfig::homogeneous("tiny", 2, &[("core", 4)], 0)
}

/// Assemble the pieces of one run: deterministic options (no measured
/// time, no RSS probe), snapshot-grade event log, CSV outputs at `jobs`/
/// `perf`, scenario compiled against the system and seed.
fn parts(
    swf: &Path,
    label: &str,
    scenario: Option<&ScenarioSpec>,
    seed: u64,
    jobs: &Path,
    perf: &Path,
) -> (Box<dyn JobSource>, SysConfig, Dispatcher, SimOptions) {
    let sys = tiny_sys();
    let d = dispatcher_from_label(label).unwrap();
    let mut addons = Vec::new();
    let mut warps = Vec::new();
    if let Some(sc) = scenario {
        let compiled = sc.compile(seed, sys.total_nodes()).unwrap();
        warps = compiled.warps;
        addons = compiled.addons;
    }
    let output = OutputCollector::in_memory(true, true)
        .with_job_file(jobs)
        .unwrap()
        .with_perf_file(perf)
        .unwrap();
    let opts = SimOptions {
        output,
        addons,
        seed,
        time_dispatch: false,
        mem_sample_secs: 0,
        retain_log: true,
        ..Default::default()
    };
    let source = SwfSource::open(swf, &sys, opts.factory.clone()).unwrap();
    let source = WarpedSource::wrap(Box::new(source), warps);
    (source, sys, d, opts)
}

/// The contract itself: reference run vs snapshot-at-`k`-points → restore
/// → completion, compared byte-for-byte on both CSVs.
fn assert_resume_byte_identical(
    dir: &Path,
    swf: &Path,
    label: &str,
    scenario: Option<&ScenarioSpec>,
    seed: u64,
    k: u64,
) {
    let tag = format!("{label}-{}-{seed}-{k}", scenario.map_or("plain", |s| s.name.as_str()));
    let ref_jobs = dir.join(format!("{tag}-ref-jobs.csv"));
    let ref_perf = dir.join(format!("{tag}-ref-perf.csv"));
    let (source, sys, d, opts) = parts(swf, label, scenario, seed, &ref_jobs, &ref_perf);
    let mut reference = SimCore::with_source(source, sys, d, opts);
    let ref_out = reference.run().unwrap();

    // interrupted twin: advance k time points, snapshot, drop
    let scratch_jobs = dir.join(format!("{tag}-scratch-jobs.csv"));
    let scratch_perf = dir.join(format!("{tag}-scratch-perf.csv"));
    let (source, sys, d, opts) = parts(swf, label, scenario, seed, &scratch_jobs, &scratch_perf);
    let mut interrupted = SimCore::with_source(source, sys, d, opts);
    for i in 0..k {
        match interrupted.step().unwrap() {
            Step::Advanced(_) => {}
            Step::Idle | Step::Done => panic!("{tag}: run ended after {i} points (k={k})"),
        }
    }
    let snap = interrupted.snapshot().unwrap();
    drop(interrupted);

    // restore into entirely fresh parts (fresh source from the beginning,
    // fresh collectors writing fresh files) and play to completion
    let res_jobs = dir.join(format!("{tag}-res-jobs.csv"));
    let res_perf = dir.join(format!("{tag}-res-perf.csv"));
    let (source, sys, d, opts) = parts(swf, label, scenario, seed, &res_jobs, &res_perf);
    let mut restored = SimCore::restore(&snap, source, sys, d, opts).unwrap();
    let res_out = restored.run().unwrap();

    assert_eq!(read(&ref_jobs), read(&res_jobs), "{tag}: jobs.csv diverged after restore");
    assert_eq!(read(&ref_perf), read(&res_perf), "{tag}: perf.csv diverged after restore");
    assert_eq!(
        (ref_out.jobs_completed, ref_out.jobs_rejected, ref_out.makespan),
        (res_out.jobs_completed, res_out.jobs_rejected, res_out.makespan),
        "{tag}: summary diverged after restore"
    );
}

/// Every shipped scheduler, each paired with one of the three allocators
/// so all allocators are covered too.
const SCHEDULERS: [&str; 12] = [
    "FIFO", "SJF", "LJF", "FIFO_RND", "SJF_RND", "LJF_RND", "EBF", "EBF_SJF", "EBF_LJF", "CBF",
    "PCAP", "REJECT",
];

#[test]
fn every_shipped_dispatcher_resumes_byte_identically() {
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    varied_swf(&swf, 30);
    let allocators = ["FF", "BF", "WF"];
    for (i, sched) in SCHEDULERS.iter().enumerate() {
        let label = format!("{sched}-{}", allocators[i % allocators.len()]);
        assert_resume_byte_identical(tmp.path(), &swf, &label, None, 7, 5);
    }
}

#[test]
fn failure_storm_scenario_resumes_byte_identically() {
    // The storm's compiled failure injector carries pending repairs across
    // the snapshot: nodes down at the midpoint must come back up at the
    // exact original instant in the restored run.
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    varied_swf(&swf, 30);
    let storm = ScenarioSpec::named("storm").with_perturbation(Perturbation::FailureStorm {
        from: 0,
        until: 4000,
        storms: 2,
        width: 1,
        repair: 2000,
    });
    for k in [3, 9] {
        assert_resume_byte_identical(tmp.path(), &swf, "FIFO-FF", Some(&storm), 11, k);
    }
}

#[test]
fn power_cap_scenario_resumes_byte_identically() {
    // The power model integrates energy and the cap schedule steps over
    // time; both live in addon snapshot state, and PCAP reads the
    // published cap metric — midpoint restores must not lose a joule.
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    varied_swf(&swf, 30);
    let daycap = ScenarioSpec {
        power: Some(PowerSpec { idle_w: 100.0, max_w: 300.0, cadence: 600 }),
        ..ScenarioSpec::named("daycap")
    }
    .with_perturbation(Perturbation::PowerCap {
        steps: vec![(0, 100_000.0), (1500, 450.0), (5000, 100_000.0)],
        watts_per_slot: 50.0,
    });
    for k in [4, 10] {
        assert_resume_byte_identical(tmp.path(), &swf, "PCAP-FF", Some(&daycap), 3, k);
    }
}

#[test]
fn backfilling_profile_resumes_byte_identically() {
    // Snapshot/restore round-trips with the incremental backfilling
    // profile active (the default). Restore re-registers every still-
    // running job from its committed start (`allocate_running`), so the
    // restored profile must answer every later probe exactly as the
    // uninterrupted one — and exactly as a run on the naive oracle path.
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    varied_swf(&swf, 30);
    for label in ["EBF-FF", "EBF_SJF-BF", "CBF-FF"] {
        for k in [3, 8] {
            assert_resume_byte_identical(tmp.path(), &swf, label, None, 13, k);
        }
        // The restored profile-on run must also match an uninterrupted
        // profile-off twin byte-for-byte: restore-time registration and
        // the naive rebuild describe the same availability future.
        let naive_jobs = tmp.path().join(format!("{label}-naive-jobs.csv"));
        let naive_perf = tmp.path().join(format!("{label}-naive-perf.csv"));
        let (source, sys, d, mut opts) =
            parts(&swf, label, None, 13, &naive_jobs, &naive_perf);
        opts.use_backfill_profile = false;
        let mut naive = SimCore::with_source(source, sys, d, opts);
        naive.run().unwrap();
        // files written by assert_resume_byte_identical's restored twin
        let tag = format!("{label}-plain-13-8");
        assert_eq!(
            read(&naive_jobs),
            read(&tmp.path().join(format!("{tag}-res-jobs.csv"))),
            "{label}: restored profile run diverged from the naive path"
        );
        assert_eq!(
            read(&naive_perf),
            read(&tmp.path().join(format!("{tag}-res-perf.csv"))),
            "{label}: restored profile perf diverged from the naive path"
        );
    }
}

#[test]
fn snapshot_text_is_stable_across_a_snapshot_restore_cycle() {
    // Restoring a snapshot and snapshotting again without stepping must
    // reproduce the document byte-for-byte — the serialized state is
    // closed under restore.
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    varied_swf(&swf, 20);
    let jobs = tmp.path().join("a-jobs.csv");
    let perf = tmp.path().join("a-perf.csv");
    let (source, sys, d, opts) = parts(&swf, "EBF-BF", None, 1, &jobs, &perf);
    let mut sim = SimCore::with_source(source, sys, d, opts);
    for _ in 0..6 {
        assert!(matches!(sim.step().unwrap(), Step::Advanced(_)));
    }
    let snap = sim.snapshot().unwrap();
    let jobs2 = tmp.path().join("b-jobs.csv");
    let perf2 = tmp.path().join("b-perf.csv");
    let (source, sys, d, opts) = parts(&swf, "EBF-BF", None, 1, &jobs2, &perf2);
    let restored = SimCore::restore(&snap, source, sys, d, opts).unwrap();
    assert_eq!(restored.snapshot().unwrap(), snap);
}
