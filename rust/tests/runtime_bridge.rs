//! Integration tests over the PJRT runtime bridge: load the AOT artifacts,
//! execute them from Rust, and check numerics against the pure-Rust
//! implementations (the same contract pytest enforces against ref.py).
//!
//! Requires `make artifacts`; tests are skipped (with a notice) otherwise.

use accasim::config::SysConfig;
use accasim::dispatch::{Allocator, BestFit, XlaFit};
use accasim::resources::{Allocation, ResourceManager};
use accasim::rng::Pcg64;
use accasim::runtime::{shapes, Engine};
use accasim::workload::Job;
use std::sync::Arc;

fn engine() -> Option<Arc<Engine>> {
    if !std::path::Path::new("artifacts/fit_score.hlo.txt").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Engine::with_artifacts("artifacts").expect("engine loads artifacts")))
}

#[test]
fn loads_all_artifacts() {
    let Some(e) = engine() else { return };
    for name in ["fit_score", "metrics", "slot_hist"] {
        assert!(e.has(name), "{name} should be loaded");
    }
}

#[test]
fn fit_score_roundtrip_matches_rust_semantics() {
    let Some(e) = engine() else { return };
    // job 0: 2 cores, 10 mem per slot
    let mut req = vec![0f32; shapes::FIT_J * shapes::FIT_R];
    req[0] = 2.0;
    req[1] = 10.0;
    let mut free = vec![0f32; shapes::FIT_N * shapes::FIT_R];
    let mut busy = vec![-1f32; shapes::FIT_N];
    // node 0: feasible, busy 3; node 1: infeasible (1 core); node 2: busy 7
    for (n, (c, m, b)) in [(4.0, 100.0, 3.0), (1.0, 100.0, 0.0), (8.0, 50.0, 7.0)]
        .iter()
        .enumerate()
    {
        free[n * shapes::FIT_R] = *c;
        free[n * shapes::FIT_R + 1] = *m;
        busy[n] = *b;
    }
    let out = e
        .execute_f32(
            "fit_score",
            &[
                (&req, &[shapes::FIT_J as i64, shapes::FIT_R as i64]),
                (&free, &[shapes::FIT_N as i64, shapes::FIT_R as i64]),
                (&busy, &[shapes::FIT_N as i64]),
            ],
        )
        .unwrap();
    let score = &out[0];
    let host = &out[1];
    assert_eq!(score[0], 3.0);
    assert_eq!(score[1], -1.0);
    assert_eq!(score[2], 7.0);
    assert_eq!(host[0], 2.0); // min(4/2, 100/10)
    assert_eq!(host[2], 4.0); // min(8/2, 50/10) = 4... min(4,5)=4
    // padded nodes infeasible
    assert_eq!(score[3], -1.0);
}

#[test]
fn metrics_roundtrip_matches_rust_stats() {
    let Some(e) = engine() else { return };
    let b = shapes::MET_B;
    let mut rng = Pcg64::new(42);
    let wait: Vec<f32> = (0..b).map(|_| rng.range_u64(0, 10_000) as f32).collect();
    let dur: Vec<f32> = (0..b).map(|_| rng.range_u64(1, 5_000) as f32).collect();
    let mask: Vec<f32> = (0..b).map(|i| if i % 7 == 0 { 0.0 } else { 1.0 }).collect();
    let out = e
        .execute_f32(
            "metrics",
            &[
                (&wait, &[b as i64]),
                (&dur, &[b as i64]),
                (&mask, &[b as i64]),
            ],
        )
        .unwrap();
    let sd = &out[0];
    let hist = &out[1];
    let summary = &out[2];
    // cross-check against rust-side slowdown math
    let mut expect_sum = 0f64;
    let mut expect_count = 0u64;
    for i in 0..b {
        let tr = dur[i].max(1.0) as f64;
        let expected = if mask[i] > 0.0 { (wait[i] as f64 + tr) / tr } else { 0.0 };
        assert!(
            (sd[i] as f64 - expected).abs() < 1e-3 * expected.max(1.0),
            "slowdown[{i}] {} vs {expected}",
            sd[i]
        );
        if mask[i] > 0.0 {
            expect_sum += expected;
            expect_count += 1;
        }
    }
    let hist_total: f32 = hist.iter().sum();
    assert_eq!(hist_total as u64, expect_count);
    assert_eq!(summary[0] as u64, expect_count);
    assert!((summary[3] as f64 - expect_sum).abs() / expect_sum < 1e-4);
}

#[test]
fn slot_hist_roundtrip() {
    let Some(e) = engine() else { return };
    let b = shapes::SLOT_B;
    let mut times = vec![0f32; b];
    let mask = vec![1f32; b];
    // all at 09:00 → slot 18
    for t in times.iter_mut() {
        *t = 9.0 * 3600.0;
    }
    times[0] = 0.0; // slot 0
    let out = e
        .execute_f32("slot_hist", &[(&times, &[b as i64]), (&mask, &[b as i64])])
        .unwrap();
    let counts = &out[0];
    let weights = &out[1];
    assert_eq!(counts[18] as usize, b - 1);
    assert_eq!(counts[0] as usize, 1);
    assert!((weights.iter().sum::<f32>() - 1.0).abs() < 1e-5);
}

#[test]
fn slot_weights_via_engine_match_cpu_fit() {
    let Some(e) = engine() else { return };
    // synthesize a seed trace, fit slot weights on CPU, re-derive via the
    // slot_hist artifact — the two paths must agree exactly
    use accasim::generator::SeedStats;
    use accasim::workload::SwfReader;
    let dir = tempfile::tempdir().unwrap();
    let p = dir.path().join("seed.swf");
    accasim::traces::SETH.synthesize(&p, 0.05, 9).unwrap(); // > one SLOT_B chunk
    let times: Vec<u64> = SwfReader::open(&p)
        .unwrap()
        .map(|r| r.unwrap().submit_time as u64)
        .collect();
    assert!(times.len() > accasim::runtime::shapes::SLOT_B);
    let recs: Vec<accasim::workload::SwfFields> = SwfReader::open(&p)
        .unwrap()
        .map(|r| r.unwrap())
        .collect();
    let cpu = SeedStats::from_records(recs.iter(), &Default::default());
    let xla = SeedStats::slot_weights_via_engine(&times, &e).unwrap();
    assert_eq!(xla.len(), cpu.slot_weights.len());
    for (k, (a, b)) in cpu.slot_weights.iter().zip(&xla).enumerate() {
        assert!((a - b).abs() < 1e-9, "slot {k}: cpu {a} vs xla {b}");
    }
}

use accasim::testutil as tempfile;

// ---------------------------------------------------------------------------
// XlaFit ≡ BestFit equivalence: same node order, same placements, end-to-end.
// ---------------------------------------------------------------------------

fn arb_rm(rng: &mut Pcg64, nodes: u64) -> ResourceManager {
    let sys = SysConfig::homogeneous(
        "t",
        nodes,
        &[("core", rng.range_u64(2, 16)), ("mem", rng.range_u64(64, 512))],
        0,
    );
    ResourceManager::from_config(&sys)
}

fn arb_job(rng: &mut Pcg64, id: u64) -> Job {
    Job {
        id,
        submit: 0,
        duration: 100,
        req_time: 100,
        slots: rng.range_u64(1, 12) as u32,
        per_slot: vec![rng.range_u64(1, 4), rng.range_u64(0, 64)],
        user: 0,
        app: 0,
        status: 1,
        shape: accasim::resources::ShapeId::UNSET,
    }
}

#[test]
fn xla_fit_orders_nodes_exactly_like_best_fit() {
    let Some(e) = engine() else { return };
    let mut xf = XlaFit::new(e).unwrap();
    let mut bf = BestFit::new();
    let mut rng = Pcg64::new(7);
    for case in 0..20 {
        let nodes = rng.range_u64(4, 64);
        let mut rm = arb_rm(&mut rng, nodes);
        // occupy some nodes to diversify busy counts
        for k in 0..rng.range_u64(0, 8) {
            let j = arb_job(&mut rng, 1000 + k);
            if let Some(a) = bf.place(&j, &rm) {
                rm.allocate(&j, a).unwrap();
            }
        }
        let job = arb_job(&mut rng, 1);
        let (mut order_bf, mut order_xf) = (Vec::new(), Vec::new());
        bf.node_order(&job, &rm, &mut order_bf);
        xf.node_order(&job, &rm, &mut order_xf);
        assert_eq!(order_bf, order_xf, "case {case}: node orders diverge");
    }
}

#[test]
fn xla_fit_placements_match_best_fit_end_to_end() {
    let Some(e) = engine() else { return };
    let mut xf = XlaFit::new(e).unwrap();
    let mut bf = BestFit::new();
    let mut rng = Pcg64::new(11);
    let mut rm_a = arb_rm(&mut rng, 32);
    let mut rm_b = rm_a.clone();
    for id in 1..=50u64 {
        let job = arb_job(&mut rng, id);
        let pa = bf.place(&job, &rm_a);
        let pb = xf.place(&job, &rm_b);
        assert_eq!(pa, pb, "job {id} placement diverged");
        if let Some(a) = pa {
            rm_a.allocate(&job, a.clone()).unwrap();
            rm_b.allocate(&job, a).unwrap();
        }
    }
    assert_eq!(rm_a.free_matrix(), rm_b.free_matrix());
}

#[test]
fn xla_fit_handles_chunked_node_counts() {
    let Some(e) = engine() else { return };
    // more nodes than one FIT_N bucket → chunked execution
    let mut xf = XlaFit::new(e).unwrap();
    let mut bf = BestFit::new();
    let mut rng = Pcg64::new(13);
    let mut rm = arb_rm(&mut rng, (shapes::FIT_N + 37) as u64);
    // make one far node the busiest
    let far = shapes::FIT_N + 10;
    let j0 = Job {
        id: 999,
        submit: 0,
        duration: 1,
        req_time: 1,
        slots: 2,
        per_slot: vec![1, 0],
        user: 0,
        app: 0,
        status: 1,
        shape: accasim::resources::ShapeId::UNSET,
    };
    rm.allocate(&j0, Allocation { slices: vec![(far as u32, 2)] }).unwrap();
    // a 1-core job fits everywhere, so the busiest (far) node must lead
    let job = Job { per_slot: vec![1, 0], slots: 1, ..j0.clone() };
    let (mut order_bf, mut order_xf) = (Vec::new(), Vec::new());
    bf.node_order(&job, &rm, &mut order_bf);
    xf.node_order(&job, &rm, &mut order_xf);
    assert_eq!(order_bf, order_xf);
    assert_eq!(order_xf[0], far as u32);
}
