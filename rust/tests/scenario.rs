//! Scenario-engine determinism and vocabulary contracts (ISSUE 4
//! acceptance): a campaign using all four perturbation kinds expands,
//! runs, resumes and compares; `--jobs N` is byte-identical to `--jobs 1`;
//! re-invocation executes 0 runs; every perturbation kind round-trips
//! through spec JSON; and storm draws key off the repetition seed.

use accasim::campaign::{run_dir, Campaign, CampaignSpec, PowerSpec, ScenarioSpec};
use accasim::config::SysConfig;
use accasim::rng::Pcg64;
use accasim::scenario::Perturbation;
use accasim::testutil as tempfile;
use std::path::Path;

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

/// Write a small fixed SWF: `n` two-slot jobs, one every 300 s.
fn tiny_swf(path: &Path, n: u64) {
    let mut text = String::from("; UnitTime: seconds\n");
    for i in 1..=n {
        let submit = (i - 1) * 300;
        text.push_str(&format!("{i} {submit} -1 600 2 -1 -1 2 1200 -1 1 1 1 1 1 1 -1 -1\n"));
    }
    std::fs::write(path, text).unwrap();
}

/// 2 nodes × 2 cores: small enough that every perturbation visibly bites.
fn tiny_sys() -> SysConfig {
    SysConfig::homogeneous("tiny", 2, &[("core", 2)], 0)
}

/// A campaign over one fixed workload exercising all four perturbation
/// kinds (plus the power/failures sugar) across 2 dispatchers × 2 seeds.
fn vocabulary_spec(swf: &Path) -> CampaignSpec {
    let mut spec = CampaignSpec::new("vocab");
    spec.add_swf(swf)
        .add_system("tiny", tiny_sys())
        .add_dispatcher("FIFO-FF")
        .add_dispatcher("SJF-FF")
        .add_scenario(ScenarioSpec::named("surge").with_perturbation(
            Perturbation::ArrivalSurge { from: 0, until: 6000, factor: 4.0 },
        ))
        .add_scenario(ScenarioSpec::named("maint").with_perturbation(
            Perturbation::Maintenance {
                from: 500,
                until: 8000,
                every: 3000,
                duration: 1000,
                width: 1,
            },
        ))
        .add_scenario(ScenarioSpec::named("storm").with_perturbation(
            Perturbation::FailureStorm {
                from: 0,
                until: 5000,
                storms: 2,
                width: 1,
                repair: 2000,
            },
        ))
        .add_scenario(
            ScenarioSpec {
                power: Some(PowerSpec { idle_w: 100.0, max_w: 300.0, cadence: 600 }),
                ..ScenarioSpec::named("daycap")
            }
            .with_perturbation(Perturbation::PowerCap {
                steps: vec![(0, 100_000.0), (2000, 500.0), (7000, 100_000.0)],
                watts_per_slot: 50.0,
            }),
        );
    spec.seeds = vec![1, 2];
    spec
}

#[test]
fn vocabulary_campaign_runs_resumes_and_compares_byte_identically() {
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    tiny_swf(&swf, 30);

    let serial_out = tmp.path().join("serial");
    let parallel_out = tmp.path().join("parallel");
    let serial = Campaign::new(vocabulary_spec(&swf), &serial_out).jobs(1).run().unwrap();
    let parallel = Campaign::new(vocabulary_spec(&swf), &parallel_out).jobs(4).run().unwrap();
    // 1 workload × 1 system × 2 dispatchers × 5 scenarios × 2 seeds
    assert_eq!(serial.records.len(), 20);
    assert_eq!((serial.executed, parallel.executed), (20, 20));

    // --jobs 4 output is byte-identical to --jobs 1
    assert_eq!(read(&serial.index), read(&parallel.index));
    for file in ["plots/fig10_slowdown.csv", "plots/fig11_queue.csv", "summary.csv"] {
        assert_eq!(read(&serial_out.join(file)), read(&parallel_out.join(file)), "{file}");
    }
    for rec in &serial.records {
        assert_eq!(
            read(&run_dir(&serial_out, &rec.run_id).join("jobs.csv")),
            read(&run_dir(&parallel_out, &rec.run_id).join("jobs.csv")),
            "{}",
            rec.run_id
        );
        assert!(rec.jobs_completed > 0, "{}", rec.run_id);
    }

    // re-running executes 0 runs and leaves the artifacts unchanged
    let before = read(&serial.index);
    let again = Campaign::new(vocabulary_spec(&swf), &serial_out).jobs(4).run().unwrap();
    assert_eq!((again.executed, again.skipped), (0, 20));
    assert_eq!(read(&again.index), before);

    // campaign compare produces per-scenario cells with effect sizes
    let cmp = again.compare(Default::default()).unwrap();
    cmp.write(&serial_out).unwrap();
    let deltas = read(&serial_out.join("comparisons/deltas.csv"));
    let header = deltas.lines().next().unwrap();
    assert!(header.contains("cliffs_delta") && header.contains("rank_biserial"), "{header}");
    for scenario in ["baseline", "surge", "maint", "storm", "daycap"] {
        assert!(
            deltas.lines().any(|l| l.contains(&format!(",{scenario},"))),
            "no per-scenario cell for {scenario} in deltas.csv:\n{deltas}"
        );
    }
}

#[test]
fn perturbations_actually_perturb_the_schedule() {
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    tiny_swf(&swf, 30);
    let report = Campaign::new(vocabulary_spec(&swf), tmp.path().join("out")).run().unwrap();
    let rec = |scenario: &str, seed: u64| {
        report
            .records
            .iter()
            .find(|r| r.dispatcher == "FIFO-FF" && r.scenario == scenario && r.seed == seed)
            .unwrap()
    };
    let baseline = rec("baseline", 1);
    // the surge compresses submissions → waits/slowdowns change
    assert_ne!(baseline.slowdown_sum, rec("surge", 1).slowdown_sum, "surge must bite");
    // maintenance takes a node out periodically → schedule changes
    assert_ne!(baseline.slowdown_sum, rec("maint", 1).slowdown_sum, "maintenance must bite");
    // the storm knocks a node out → schedule changes
    assert_ne!(baseline.slowdown_sum, rec("storm", 1).slowdown_sum, "storm must bite");
    // daycap publishes energy (power sugar) in its manifests
    assert!(rec("daycap", 1).extra.contains_key("power.energy_kj"));
}

#[test]
fn storms_key_off_the_repetition_seed() {
    // Fixed workload + deterministic dispatcher: under the baseline
    // scenario both repetition seeds replay the identical simulation, so
    // any seed-1 vs seed-2 difference inside the storm scenario is the
    // storm draw itself.
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    tiny_swf(&swf, 30);
    let out = tmp.path().join("out");
    let report = Campaign::new(vocabulary_spec(&swf), &out).run().unwrap();
    let jobs_csv = |scenario: &str, seed: u64| {
        let rec = report
            .records
            .iter()
            .find(|r| r.dispatcher == "FIFO-FF" && r.scenario == scenario && r.seed == seed)
            .unwrap();
        read(&run_dir(&out, &rec.run_id).join("jobs.csv"))
    };
    assert_eq!(
        jobs_csv("baseline", 1),
        jobs_csv("baseline", 2),
        "fixed workload + FIFO: repetitions replay identically without a storm"
    );
    assert_ne!(
        jobs_csv("storm", 1),
        jobs_csv("storm", 2),
        "storm draws must differ across repetition seeds"
    );
}

#[test]
fn prop_random_scenarios_replay_byte_identically() {
    // Property: ANY scenario spec — here a seeded family of randomly
    // parameterized vocabularies — replays byte-identically across
    // re-invocation and across --jobs counts.
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("w.swf");
    tiny_swf(&swf, 20);
    let mut rng = Pcg64::new(0xACCA);
    for case in 0..3 {
        let surge_until = rng.range_u64(1000, 8000);
        let every = rng.range_u64(500, 4000);
        let storms = rng.range_u64(1, 4) as u32;
        let cap_at = rng.range_u64(100, 6000);
        let scenario = ScenarioSpec::named("random")
            .with_perturbation(Perturbation::ArrivalSurge {
                from: 0,
                until: surge_until,
                factor: 1.0 + rng.f64() * 7.0,
            })
            .with_perturbation(Perturbation::Maintenance {
                from: rng.range_u64(0, 500),
                until: 9000,
                every,
                duration: rng.range_u64(1, every),
                width: 1,
            })
            .with_perturbation(Perturbation::FailureStorm {
                from: 0,
                until: 5000,
                storms,
                width: 1 + (case % 2) as u32,
                repair: rng.range_u64(500, 3000),
            })
            .with_perturbation(Perturbation::PowerCap {
                steps: vec![(0, 100_000.0), (cap_at, 400.0 + rng.f64() * 200.0)],
                watts_per_slot: 50.0,
            });
        let mut spec = CampaignSpec::new(&format!("prop{case}"));
        spec.add_swf(&swf).add_system("tiny", tiny_sys()).add_dispatcher("FIFO-FF");
        spec.scenarios = vec![scenario];
        spec.seeds = vec![1];
        spec.validate().unwrap();

        // the spec (including every random perturbation) survives JSON
        let back = CampaignSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.scenarios, spec.scenarios, "case {case}");
        assert_eq!(back.spec_hash().unwrap(), spec.spec_hash().unwrap(), "case {case}");

        let a_out = tmp.path().join(format!("a{case}"));
        let b_out = tmp.path().join(format!("b{case}"));
        let a = Campaign::new(spec.clone(), &a_out).jobs(1).run().unwrap();
        let b = Campaign::new(back, &b_out).jobs(3).run().unwrap();
        assert_eq!(read(&a.index), read(&b.index), "case {case}");
        assert_eq!(
            read(&run_dir(&a_out, &a.records[0].run_id).join("jobs.csv")),
            read(&run_dir(&b_out, &b.records[0].run_id).join("jobs.csv")),
            "case {case}"
        );
        let again = Campaign::new(spec, &a_out).run().unwrap();
        assert_eq!((again.executed, again.skipped), (0, 1), "case {case}");
    }
}

#[test]
fn random_tie_break_dispatchers_are_seed_sensitive_yet_reproducible() {
    // 8 identical jobs submitted together on an 8-way machine: SJF_RND
    // shuffles the tie by the run seed. Same seed → byte-identical
    // records; different repetition seeds → different start order.
    let tmp = tempfile::tempdir().unwrap();
    let swf = tmp.path().join("ties.swf");
    let mut text = String::new();
    for i in 1..=8 {
        text.push_str(&format!("{i} 0 -1 600 2 -1 -1 2 1200 -1 1 1 1 1 1 1 -1 -1\n"));
    }
    std::fs::write(&swf, text).unwrap();
    let spec = |name: &str, seeds: Vec<u64>| {
        let mut s = CampaignSpec::new(name);
        s.add_swf(&swf)
            .add_system("tiny", SysConfig::homogeneous("tiny", 1, &[("core", 2)], 0))
            .add_dispatcher("SJF_RND-FF");
        s.seeds = seeds;
        s
    };
    let out1 = tmp.path().join("o1");
    let out2 = tmp.path().join("o2");
    let a = Campaign::new(spec("ties", vec![1, 2]), &out1).run().unwrap();
    let b = Campaign::new(spec("ties", vec![1, 2]), &out2).run().unwrap();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(
            read(&run_dir(&out1, &ra.run_id).join("jobs.csv")),
            read(&run_dir(&out2, &rb.run_id).join("jobs.csv")),
            "same seed must replay the same tie order"
        );
    }
    // on a 1-node × 2-core machine the 8 two-slot jobs serialize: the tie
    // order is fully visible in the start times, so the two repetition
    // seeds must schedule differently
    assert_ne!(
        read(&run_dir(&out1, &a.records[0].run_id).join("jobs.csv")),
        read(&run_dir(&out1, &a.records[1].run_id).join("jobs.csv")),
        "repetition seeds must exercise dispatcher nondeterminism"
    );
}

#[test]
fn simulate_cli_applies_a_scenario_file_and_warns_on_skipped_lines() {
    let dir = tempfile::tempdir().unwrap();
    let swf = dir.path().join("w.swf");
    // one malformed line in the middle (on its own line)
    let mut text = String::new();
    for i in 1..=10u64 {
        if i == 5 {
            text.push_str("this line is broken\n");
        }
        text.push_str(&format!("{i} {} -1 600 2 -1 -1 2 1200 -1 1 1 1 1 1 1 -1 -1\n", i * 300));
    }
    std::fs::write(&swf, text).unwrap();
    let cfg = dir.path().join("sys.json");
    tiny_sys().write_json_file(&cfg).unwrap();
    let scenario = dir.path().join("scenario.json");
    std::fs::write(
        &scenario,
        r#"{
            "name": "demo",
            "power": {"idle_w": 100, "max_w": 300, "cadence": 600},
            "perturbations": [
                {"kind": "arrival_surge", "from": 0, "until": 3000, "factor": 4},
                {"kind": "failure_storm", "from": 0, "until": 2000,
                 "storms": 1, "width": 1, "repair": 900}
            ]
        }"#,
    )
    .unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_accasim"))
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--scenario",
            scenario.to_str().unwrap(),
            "--seed",
            "7",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("power.energy_kj"), "scenario power model attached:\n{stdout}");
    assert!(stdout.contains("failures.down_nodes"), "storm compiled into failures:\n{stdout}");
    assert!(
        stderr.contains("1 malformed workload line(s) skipped"),
        "skip warning missing:\n{stderr}"
    );

    // a broken scenario file is a clear error
    std::fs::write(&scenario, r#"{"name": "bad", "perturbations": [{"kind": "quake"}]}"#)
        .unwrap();
    let bad = std::process::Command::new(env!("CARGO_BIN_EXE_accasim"))
        .args([
            "simulate",
            swf.to_str().unwrap(),
            "--sys",
            cfg.to_str().unwrap(),
            "--scenario",
            scenario.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("quake"));
}
