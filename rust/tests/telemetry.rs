//! Telemetry invariants (DESIGN.md §Observability).
//!
//! Two families of guarantees:
//!
//! 1. **Observation-only** — simulations and whole campaigns executed with
//!    telemetry disabled, enabled, and enabled-with-tracing must produce
//!    byte-identical outputs (including under a failure-storm scenario);
//!    at the campaign level the *only* store differences are the
//!    observation artifacts themselves (`telemetry.json`,
//!    `timeseries.csv`).
//! 2. **Valid traces & live status** — `chrome_trace()` parses as Chrome
//!    trace-event JSON with complete (`ph == "X"`) events, placements
//!    nested inside their dispatch cycles and cycles disjoint in time;
//!    `campaign status` classifies runs into done/active/stale/pending by
//!    heartbeat age.

use accasim::addons::FailureInjector;
use accasim::config::SysConfig;
use accasim::dispatch::dispatcher_from_label;
use accasim::output::OutputCollector;
use accasim::rng::Pcg64;
use accasim::sim::{SimOptions, SimOutput, Simulator};
use accasim::telemetry::{SpanKind, Telemetry, DEFAULT_STALE_AFTER_SECS, HEARTBEAT_FILE};
use accasim::testkit::arb_jobs;
use accasim::testutil as tempfile;
use accasim::util::json::Json;
use accasim::workload::Job;

/// Render the deterministic portion of a run: the full jobs.csv bytes plus
/// the timing-free perf columns (dispatch/other ns and RSS are wall-clock
/// noise and excluded by design — same rule as `rust/tests/availability_index.rs`).
fn deterministic_bytes(out: &SimOutput) -> String {
    let mut s = String::from("jobs.csv\n");
    for j in &out.jobs {
        s.push_str(&j.to_csv());
        s.push('\n');
    }
    s.push_str("perf(t,queue,running,started)\n");
    for p in &out.perf {
        s.push_str(&format!("{},{},{},{}\n", p.t, p.queue_len, p.running, p.started));
    }
    s.push_str(&format!(
        "completed={} rejected={} makespan={} slowdown_sum={} wait_sum={} max_queue={}\n",
        out.jobs_completed,
        out.jobs_rejected,
        out.makespan,
        out.slowdown_sum,
        out.wait_sum,
        out.max_queue
    ));
    s
}

fn run_with_telemetry(jobs: Vec<Job>, sys: SysConfig, label: &str, tel: Telemetry) -> SimOutput {
    let opts = SimOptions {
        output: OutputCollector::in_memory(true, true),
        mem_sample_secs: 0,
        telemetry: tel,
        ..Default::default()
    };
    let mut sim = Simulator::from_jobs(jobs, sys, dispatcher_from_label(label).unwrap(), opts);
    sim.run().expect("simulation completes")
}

/// Byte identity across the telemetry toggle, for every dispatcher family:
/// metrics collection and span tracing must not change a single result.
#[test]
fn simulations_are_byte_identical_with_telemetry_on() {
    let mut rng = Pcg64::new(0x7E1E);
    let jobs = arb_jobs(&mut rng, 120, 12, 3);
    let sys = SysConfig::homogeneous("tel", 6, &[("core", 8), ("gpu", 1), ("mem", 64)], 0);
    for label in ["FIFO-FF", "SJF-BF", "LJF-WF", "EBF-FF", "CBF-FF", "FIFO_RND-FF"] {
        let off = run_with_telemetry(jobs.clone(), sys.clone(), label, Telemetry::disabled());
        let on = run_with_telemetry(jobs.clone(), sys.clone(), label, Telemetry::enabled());
        let traced_tel = Telemetry::with_trace();
        let traced =
            run_with_telemetry(jobs.clone(), sys.clone(), label, traced_tel.clone());
        assert_eq!(
            deterministic_bytes(&off),
            deterministic_bytes(&on),
            "{label}: metrics collection changed simulation results"
        );
        assert_eq!(
            deterministic_bytes(&off),
            deterministic_bytes(&traced),
            "{label}: span tracing changed simulation results"
        );
        assert!(off.jobs_completed > 0, "{label}: degenerate case");
        // and the observation actually observed something
        let s = traced_tel.summary().unwrap();
        assert!(s.dispatch_count >= traced.time_points, "{label}: cycles not timed");
        assert!(s.place_count > 0, "{label}: placements not timed");
    }
}

/// Same guarantee under capacity perturbations: a failure storm drives the
/// availability-index journal and the addon wake path while telemetry
/// watches both.
#[test]
fn failure_storms_are_byte_identical_with_telemetry_on() {
    let mut rng = Pcg64::new(0x5708);
    let jobs = arb_jobs(&mut rng, 80, 8, 2);
    let sys = SysConfig::homogeneous("telf", 4, &[("core", 8), ("mem", 64)], 0);
    let run = |tel: Telemetry| {
        let opts = SimOptions {
            output: OutputCollector::in_memory(true, true),
            addons: vec![Box::new(FailureInjector::new(vec![
                (0, 100, 5_000),
                (1, 2_000, 20_000),
                (2, 100, 3_000),
            ]))],
            mem_sample_secs: 0,
            telemetry: tel,
            ..Default::default()
        };
        let mut sim = Simulator::from_jobs(
            jobs.clone(),
            sys.clone(),
            dispatcher_from_label("FIFO-FF").unwrap(),
            opts,
        );
        sim.run().expect("simulation completes")
    };
    let off = run(Telemetry::disabled());
    let tel = Telemetry::with_trace();
    let on = run(tel.clone());
    assert_eq!(deterministic_bytes(&off), deterministic_bytes(&on));
    assert_eq!(off.addon_wakes, on.addon_wakes);
    let reg = tel.registry().unwrap();
    assert!(
        reg.histogram(SpanKind::AddonUpdate).count() > 0,
        "failure windows must drive timed addon updates"
    );
    assert!(
        tel.summary().unwrap().journal_syncs > 0,
        "node down/up transitions must drive timed journal syncs"
    );
}

/// Campaign-level observation-only: the same matrix executed with
/// telemetry on and off leaves stores that differ only in the
/// observation artifacts themselves — `telemetry.json` and the
/// time-series CSV derived from the event log.
#[test]
fn campaign_store_differs_only_by_telemetry_json() {
    use accasim::campaign::{Campaign, CampaignSpec};
    let tmp = tempfile::tempdir().unwrap();
    let spec = || {
        let mut s = CampaignSpec::new("abtel");
        s.add_trace("seth", 0.0005).add_system_trace("seth");
        s.add_dispatcher("FIFO-FF").add_dispatcher("SJF-BF");
        s.seeds = vec![1, 2];
        s
    };
    let dir_on = tmp.path().join("on");
    let dir_off = tmp.path().join("off");
    let rep_on = Campaign::new(spec(), &dir_on).telemetry(true).run().unwrap();
    let rep_off = Campaign::new(spec(), &dir_off).telemetry(false).run().unwrap();
    assert_eq!(rep_on.records.len(), 4);
    assert_eq!(rep_on.records.len(), rep_off.records.len());

    let read = |p: &std::path::Path| std::fs::read_to_string(p).unwrap();
    for file in ["summary.csv", "index.json", "plots/fig10_slowdown.csv", "plots/fig11_queue.csv"]
    {
        assert_eq!(
            read(&dir_on.join(file)),
            read(&dir_off.join(file)),
            "{file} must not depend on telemetry"
        );
    }
    for rec in &rep_on.records {
        let run = |d: &std::path::Path| d.join("runs").join(&rec.run_id);
        assert_eq!(
            read(&run(&dir_on).join("jobs.csv")),
            read(&run(&dir_off).join("jobs.csv")),
            "{}: jobs.csv must not depend on telemetry",
            rec.run_id
        );
        let strip = |text: String| {
            // keep the deterministic perf columns: t,queue_len,running,started
            text.lines()
                .skip(1)
                .map(|l| {
                    let f: Vec<&str> = l.split(',').collect();
                    format!("{},{},{},{}", f[0], f[3], f[4], f[5])
                })
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(
            strip(read(&run(&dir_on).join("perf.csv"))),
            strip(read(&run(&dir_off).join("perf.csv"))),
            "{}: perf.csv deterministic columns diverged",
            rec.run_id
        );
        // the only store differences: the observation artifacts
        for artifact in ["telemetry.json", "timeseries.csv"] {
            assert!(run(&dir_on).join(artifact).exists(), "{}: {artifact}", rec.run_id);
            assert!(!run(&dir_off).join(artifact).exists(), "{}: {artifact}", rec.run_id);
        }
        let doc = Json::parse(&read(&run(&dir_on).join("telemetry.json"))).unwrap();
        assert!(doc.get("counters").is_some() && doc.get("spans").is_some());
        assert!(
            doc.get("timeseries").is_some(),
            "{}: recorder summary folds into telemetry.json",
            rec.run_id
        );
    }
}

/// The exported trace is valid Chrome trace-event JSON whose spans nest
/// and order the way the synchronous call stack says they must:
/// dispatch cycles disjoint and time-ordered, every allocator placement
/// inside some dispatch cycle.
#[test]
fn chrome_trace_parses_and_spans_nest() {
    let mut rng = Pcg64::new(0x7ACE);
    let jobs = arb_jobs(&mut rng, 60, 6, 2);
    let sys = SysConfig::homogeneous("tr", 4, &[("core", 8), ("mem", 64)], 0);
    let tel = Telemetry::with_trace();
    run_with_telemetry(jobs, sys, "FIFO-FF", tel.clone());

    let text = tel.chrome_trace().expect("with_trace() buffers spans");
    let doc = Json::parse(&text).expect("chrome trace is valid JSON");
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ns"));
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "an instrumented run must emit events");
    for ev in events {
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"), "complete spans only");
        assert_eq!(ev.get("cat").unwrap().as_str(), Some("sim"));
        assert_eq!(ev.get("pid").unwrap().as_u64(), Some(1));
        assert_eq!(ev.get("tid").unwrap().as_u64(), Some(1));
        assert!(ev.get("ts").unwrap().as_f64().is_some());
        assert!(ev.get("dur").unwrap().as_f64().is_some());
        assert!(ev.get("args").unwrap().as_obj().is_some());
    }

    // [start, end] in µs, as the viewer reads them
    let span = |ev: &Json| -> (f64, f64) {
        let ts = ev.get("ts").unwrap().as_f64().unwrap();
        (ts, ts + ev.get("dur").unwrap().as_f64().unwrap())
    };
    let named = |n: &str| -> Vec<(f64, f64)> {
        events
            .iter()
            .filter(|e| e.get("name").unwrap().as_str() == Some(n))
            .map(span)
            .collect()
    };
    let mut cycles = named("dispatch_cycle");
    assert!(!cycles.is_empty());
    cycles.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // sequential hot loop ⇒ cycles are disjoint and time-ordered
    const EPS: f64 = 1e-6; // 0.001 ns in µs: serialization rounding headroom
    for w in cycles.windows(2) {
        assert!(w[0].1 <= w[1].0 + EPS, "dispatch cycles overlap: {w:?}");
    }
    let places = named("allocator_place");
    assert!(!places.is_empty());
    for p in &places {
        assert!(
            cycles.iter().any(|c| c.0 - EPS <= p.0 && p.1 <= c.1 + EPS),
            "placement span {p:?} escapes every dispatch cycle"
        );
    }
}

/// `campaign status` heartbeat classification through the public API:
/// fresh heartbeat → active (with per-run progress), old heartbeat →
/// stale under the documented 30 s default, threshold adjustable.
#[test]
fn campaign_status_classifies_by_heartbeat_age() {
    use accasim::campaign::{Campaign, CampaignSpec};
    let tmp = tempfile::tempdir().unwrap();
    let spec = || {
        let mut s = CampaignSpec::new("hb");
        s.add_trace("seth", 0.0005).add_system_trace("seth").add_dispatcher("FIFO-FF");
        s.seeds = vec![1, 2];
        s
    };
    let out = tmp.path().join("out");
    let campaign = Campaign::new(spec(), &out);
    let st = campaign.status().unwrap();
    assert_eq!(
        (st.total, st.done, st.active.len(), st.stale.len(), st.pending.len()),
        (2, 0, 0, 0, 2),
        "an untouched campaign is all pending"
    );

    // hand-write heartbeats: one fresh, one 60 s old
    let now_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_millis() as u64;
    let dirs: Vec<_> = st.pending.iter().map(|id| out.join("runs").join(id)).collect();
    for d in &dirs {
        std::fs::create_dir_all(d).unwrap();
    }
    std::fs::write(dirs[0].join(HEARTBEAT_FILE), format!("{now_ms} 500 12\n")).unwrap();
    std::fs::write(dirs[1].join(HEARTBEAT_FILE), format!("{} 200 3\n", now_ms - 60_000))
        .unwrap();

    assert_eq!(DEFAULT_STALE_AFTER_SECS, 30, "the documented default threshold");
    let st = campaign.status().unwrap(); // default threshold
    assert_eq!((st.active.len(), st.stale.len(), st.pending.len()), (1, 1, 0));
    assert_eq!((st.active[0].sim_time, st.active[0].points), (500, 12));
    assert_eq!((st.stale[0].sim_time, st.stale[0].points), (200, 3));
    assert!(st.stale[0].age_secs >= 59, "age {} s", st.stale[0].age_secs);
    // a wider threshold flips the old heartbeat back to active
    let st = campaign.status_with(120).unwrap();
    assert_eq!((st.active.len(), st.stale.len()), (2, 0));
    // completing the campaign wins over any leftover liveness files
    let report = Campaign::new(spec(), &out).run().unwrap();
    assert_eq!(report.executed, 2);
    let st = campaign.status().unwrap();
    assert_eq!((st.done, st.active.len(), st.stale.len(), st.pending.len()), (2, 0, 0, 0));
}
